//! Benchmark harness: one driver per paper table/figure (DESIGN.md §5).
//!
//! `zccl bench <id> [--out DIR]` regenerates the corresponding rows or
//! series; `zccl bench all` runs everything. Compressor-level experiments
//! (Tables 1–4, Figs. 5–8, Table 7) run REAL code on this host; the
//! cluster-scale figures (Figs. 9–15) run on the calibrated virtual-time
//! simulator with real compression ratios sampled from the actual codecs
//! (DESIGN.md §2). `crosscheck` validates the simulator against real
//! in-process runs at small scale.

use std::path::Path;
use std::time::Duration;

use crate::apps::{image_stacking, visualize};
use crate::collectives::{run_ranks, run_ranks_on, Algo, CollCtx, Communicator, Mode, ReduceOp};
use crate::compress::stats::{error_histogram, quality};
use crate::compress::{self, bits, Compressor, CompressorKind, ErrorBound, MtCompressor};
use crate::data::fields::{Field, FieldKind};
use crate::data::rng::Rng;
use crate::sim::calibrate::{pick_allreduce_algo, sample_ratio};
use crate::sim::collectives::{
    sim_allgather, sim_allreduce, sim_allreduce_hier, sim_bcast, sim_reduce_scatter,
    sim_scatter, SimParams,
};
use crate::sim::CostModel;
use crate::topology::Topology;
use crate::transport::crc32c;
use crate::transport::fault::{FaultPlan, FaultTransport};
use crate::transport::memchan::MemFabric;
use crate::util::bench::{emit_bench_line, measure_for, Table};
use crate::util::json::Json;
use crate::Result;

const RELS: [f64; 4] = [1e-1, 1e-2, 1e-3, 1e-4];
/// Values per field sample for the real compressor benchmarks (4 MiB of
/// f32 — large enough to be out of L2, small enough for a 1-core box).
const BENCH_VALUES: usize = 1 << 20;
/// Measurement budget per cell.
const BUDGET_S: f64 = 0.08;

/// All bench ids, in DESIGN.md §5 order.
pub const ALL: &[&str] = &[
    "table1", "table2", "table3", "table4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10",
    "fig11", "fig12", "fig13", "fig14", "fig15", "table7", "crosscheck", "hier", "codec",
    "overlap", "ablation-chunk", "ablation-balance", "ablation-eb", "chaos",
];

/// Run one bench (or `all`), printing tables and writing CSVs to
/// `out_dir`. `budget` overrides the per-cell measurement budget in
/// seconds where a bench supports it (currently `codec`; CI uses a small
/// value so `BENCH_codec.json` is produced on every run).
pub fn run(id: &str, out_dir: &Path, budget: Option<f64>) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    if id == "all" {
        for b in ALL {
            run(b, out_dir, budget)?;
        }
        return Ok(());
    }
    let t0 = std::time::Instant::now();
    let tables: Vec<(String, Table)> = match id {
        "table1" => table_throughput(false),
        "table2" => table_throughput(true),
        "table3" => table3(),
        "table4" => table4(),
        "fig5" => fig5(false),
        "fig6" => fig5(true),
        "fig7" => fig7(),
        "fig8" => fig8(out_dir)?,
        "fig9" => fig9(),
        "fig10" => fig10(),
        "fig11" => fig11(),
        "fig12" => fig12(),
        "fig13" => fig13(),
        "fig14" => fig_tree("fig14-bcast", sim_bcast),
        "fig15" => fig_tree("fig15-scatter", sim_scatter),
        "table7" => table7(out_dir)?,
        "crosscheck" => crosscheck(),
        "hier" => {
            let (tables, summary) = hier_bench(budget.unwrap_or(BUDGET_S));
            emit_bench_line("BENCH_hier.json", &summary);
            tables
        }
        "codec" => {
            let (tables, summary) = codec_bench(BENCH_VALUES, budget.unwrap_or(BUDGET_S));
            emit_bench_line("BENCH_codec.json", &summary);
            tables
        }
        "overlap" => {
            let (tables, summary) = overlap_bench(budget.unwrap_or(BUDGET_S));
            emit_bench_line("BENCH_overlap.json", &summary);
            tables
        }
        "chaos" => {
            let (tables, summary) = chaos_bench(budget.unwrap_or(BUDGET_S));
            emit_bench_line("BENCH_chaos.json", &summary);
            tables
        }
        "ablation-chunk" => ablation_chunk(),
        "ablation-balance" => ablation_balance(),
        "ablation-eb" => ablation_eb(),
        other => {
            return Err(crate::Error::invalid(format!(
                "unknown bench '{other}' (available: {})",
                ALL.join(", ")
            )))
        }
    };
    for (name, table) in tables {
        println!("== {name} ==");
        println!("{}", table.render());
        let path = out_dir.join(format!("{name}.csv"));
        std::fs::write(&path, table.to_csv())?;
        println!("-> {}", path.display());
    }
    println!("[{id} done in {:.1}s]\n", t0.elapsed().as_secs_f64());
    Ok(())
}

fn field(kind: FieldKind) -> Field {
    Field::generate(kind, BENCH_VALUES, 42)
}

/// Tables 1–2: compression/decompression throughput (GB/s) per codec ×
/// dataset × REL bound; single- or multi-thread codecs.
fn table_throughput(mt: bool) -> Vec<(String, Table)> {
    let name = if mt { "table2-throughput-mt" } else { "table1-throughput-st" };
    let mut t = Table::new(&["codec", "dataset", "rel", "comp GB/s", "decomp GB/s", "ratio"]);
    for kind in [CompressorKind::FzLight, CompressorKind::Szx] {
        for fk in FieldKind::ALL {
            let f = field(fk);
            let bytes = f.values.len() * 4;
            for rel in RELS {
                let eb = ErrorBound::Rel(rel);
                let codec: Box<dyn Compressor> = if mt {
                    Box::new(MtCompressor::new(kind))
                } else {
                    compress::build(kind)
                };
                let frame = codec.compress(&f.values, eb).expect("compress");
                let c = measure_for(BUDGET_S, || codec.compress(&f.values, eb).unwrap());
                let d = measure_for(BUDGET_S, || codec.decompress(&frame.bytes).unwrap());
                t.row(vec![
                    kind.name().into(),
                    fk.name().into(),
                    format!("{rel:.0e}"),
                    format!("{:.2}", c.gbps(bytes)),
                    format!("{:.2}", d.gbps(bytes)),
                    format!("{:.2}", frame.stats.ratio()),
                ]);
            }
        }
    }
    vec![(name.into(), t)]
}

/// Table 3: compression ratio + constant-block percentage.
fn table3() -> Vec<(String, Table)> {
    let mut t = Table::new(&["codec", "dataset", "rel", "ratio", "const-block %"]);
    for kind in [CompressorKind::FzLight, CompressorKind::Szx] {
        for fk in FieldKind::ALL {
            let f = field(fk);
            for rel in RELS {
                let c = compress::build(kind).compress(&f.values, ErrorBound::Rel(rel)).unwrap();
                t.row(vec![
                    kind.name().into(),
                    fk.name().into(),
                    format!("{rel:.0e}"),
                    format!("{:.2}", c.stats.ratio()),
                    format!("{:.2}", c.stats.constant_fraction() * 100.0),
                ]);
            }
        }
    }
    vec![("table3-ratio".into(), t)]
}

/// Table 4: NRMSE + error std per codec × dataset × bound.
fn table4() -> Vec<(String, Table)> {
    let mut t = Table::new(&["codec", "dataset", "rel", "NRMSE", "err STD", "PSNR dB"]);
    for kind in [CompressorKind::FzLight, CompressorKind::Szx] {
        for fk in FieldKind::ALL {
            let f = field(fk);
            for rel in RELS {
                let codec = compress::build(kind);
                let c = codec.compress(&f.values, ErrorBound::Rel(rel)).unwrap();
                let dec = codec.decompress(&c.bytes).unwrap();
                let q = quality(&f.values, &dec);
                t.row(vec![
                    kind.name().into(),
                    fk.name().into(),
                    format!("{rel:.0e}"),
                    format!("{:.2e}", q.nrmse),
                    format!("{:.0e}", q.err_std),
                    format!("{:.1}", q.psnr),
                ]);
            }
        }
    }
    vec![("table4-nrmse".into(), t)]
}

/// Figures 5–6: compression errors fit a normal distribution (MLE μ, σ,
/// KS distance). Fig 6 re-compresses the reconstruction (second hop e₂).
fn fig5(second_hop: bool) -> Vec<(String, Table)> {
    let name = if second_hop { "fig6-error-dist-e2" } else { "fig5-error-dist" };
    let mut t =
        Table::new(&["codec", "dataset", "rel", "mu", "sigma", "KS", "excess-kurtosis"]);
    for kind in [CompressorKind::FzLight, CompressorKind::Szx] {
        for fk in FieldKind::ALL {
            let f = field(fk);
            let rel = 1e-3;
            let codec = compress::build(kind);
            let (orig, dec) = if second_hop {
                let first = codec
                    .decompress(&codec.compress(&f.values, ErrorBound::Rel(rel)).unwrap().bytes)
                    .unwrap();
                let second = codec
                    .decompress(&codec.compress(&first, ErrorBound::Rel(rel)).unwrap().bytes)
                    .unwrap();
                (first, second)
            } else {
                let dec = codec
                    .decompress(&codec.compress(&f.values, ErrorBound::Rel(rel)).unwrap().bytes)
                    .unwrap();
                (f.values.clone(), dec)
            };
            let h = error_histogram(&orig, &dec, 64);
            t.row(vec![
                kind.name().into(),
                fk.name().into(),
                format!("{rel:.0e}"),
                format!("{:.2e}", h.mu),
                format!("{:.2e}", h.sigma),
                format!("{:.3}", h.ks),
                format!("{:.2}", h.excess_kurtosis),
            ]);
        }
    }
    vec![(name.into(), t)]
}

/// Figure 7: rate-distortion (bitrate vs PSNR) per codec × dataset.
fn fig7() -> Vec<(String, Table)> {
    let mut t = Table::new(&["codec", "dataset", "rel", "bitrate", "PSNR dB"]);
    for kind in [CompressorKind::FzLight, CompressorKind::Szx] {
        for fk in FieldKind::ALL {
            let f = field(fk);
            for rel in [1e-1, 3e-2, 1e-2, 3e-3, 1e-3, 3e-4, 1e-4, 3e-5, 1e-5] {
                let codec = compress::build(kind);
                let c = codec.compress(&f.values, ErrorBound::Rel(rel)).unwrap();
                let dec = codec.decompress(&c.bytes).unwrap();
                let q = quality(&f.values, &dec);
                t.row(vec![
                    kind.name().into(),
                    fk.name().into(),
                    format!("{rel:.0e}"),
                    format!("{:.3}", c.stats.bitrate()),
                    format!("{:.1}", q.psnr),
                ]);
            }
        }
    }
    vec![("fig7-rate-distortion".into(), t)]
}

/// Figure 8: visual artifacts — compress a CESM-like 2-D field with SZx
/// and fZ-light at a matched compression ratio (~8.3), dump PGMs.
fn fig8(out_dir: &Path) -> Result<Vec<(String, Table)>> {
    let (rows, cols) = (384, 512);
    let f = Field::generate_2d(FieldKind::Cesm, rows, cols, 13);
    let mut t = Table::new(&["codec", "target ratio", "achieved ratio", "NRMSE", "PSNR dB", "pgm"]);
    visualize::write_pgm(out_dir.join("fig8-original.pgm"), &f.values, rows, cols)?;
    for kind in [CompressorKind::FzLight, CompressorKind::Szx] {
        // Binary-search the error bound that hits ratio ~8.3.
        let codec = compress::build(kind);
        let (mut lo, mut hi) = (1e-7f64, 1e-1f64);
        let mut best = (0.0, Vec::new());
        for _ in 0..24 {
            let eb = (lo * hi).sqrt();
            let c = codec.compress(&f.values, ErrorBound::Rel(eb)).unwrap();
            let r = c.stats.ratio();
            best = (r, c.bytes.clone());
            if r > 8.3 {
                hi = eb;
            } else {
                lo = eb;
            }
            if (r - 8.3).abs() < 0.1 {
                break;
            }
        }
        let dec = codec.decompress(&best.1).unwrap();
        let q = quality(&f.values, &dec);
        let pgm = format!("fig8-{}.pgm", kind.name().replace(['(', ')'], "-"));
        visualize::write_pgm(out_dir.join(&pgm), &dec, rows, cols)?;
        let dpgm = format!("fig8-{}-diff.pgm", kind.name().replace(['(', ')'], "-"));
        visualize::write_pgm(
            out_dir.join(&dpgm),
            &visualize::diff_image(&f.values, &dec, 20.0),
            rows,
            cols,
        )?;
        t.row(vec![
            kind.name().into(),
            "8.3".into(),
            format!("{:.2}", best.0),
            format!("{:.2e}", q.nrmse),
            format!("{:.1}", q.psnr),
            pgm,
        ]);
    }
    Ok(vec![("fig8-visual".into(), t)])
}

fn sim_mode_rows(
    name: &str,
    sizes_mb: &[f64],
    n: usize,
    modes: &[(&str, Algo, CompressorKind, bool)],
    simfn: fn(&SimParams, &CostModel) -> crate::sim::SimReport,
) -> Vec<(String, Table)> {
    let cm = CostModel::paper_broadwell();
    let mut t = Table::new(&[
        "mode", "size MB", "nodes", "time s", "speedup-vs-MPI", "compress s", "comm s",
    ]);
    for &mb in sizes_mb {
        // Ratio sampled from the real codec on RTM-like data at 1e-4 (the
        // paper's default configuration).
        let mut mpi_time = None;
        for &(label, algo, kind, mt) in modes {
            let ratio =
                sample_ratio(kind, FieldKind::Rtm, ErrorBound::Rel(1e-4), 1 << 18, 17);
            let p = SimParams { n, bytes: mb * 1e6, algo, kind, multithread: mt, ratio };
            let r = simfn(&p, &cm);
            if algo == Algo::Plain && mpi_time.is_none() {
                mpi_time = Some(r.makespan_s);
            }
            let speedup = mpi_time.map(|m| m / r.makespan_s).unwrap_or(1.0);
            t.row(vec![
                label.into(),
                format!("{mb:.0}"),
                format!("{n}"),
                format!("{:.4}", r.makespan_s),
                format!("{:.2}", speedup),
                format!("{:.4}", r.breakdown.compress_s + r.breakdown.decompress_s),
                format!("{:.4}", r.breakdown.comm_s),
            ]);
        }
    }
    vec![(name.into(), t)]
}

/// Fig. 9: normalized Allreduce time, original MPI vs CPRP2P with four
/// compressors (64 nodes).
fn fig9() -> Vec<(String, Table)> {
    let cm = CostModel::paper_broadwell();
    let mut t = Table::new(&[
        "variant", "normalized total", "compress %", "comm %", "reduce %", "ratio",
    ]);
    let mpi = sim_allreduce(
        &SimParams {
            n: 64,
            bytes: 600e6,
            algo: Algo::Plain,
            kind: CompressorKind::FzLight,
            multithread: false,
            ratio: 1.0,
        },
        &cm,
    );
    let variants: [(&str, CompressorKind); 4] = [
        ("CPRP2P fZ-light", CompressorKind::FzLight),
        ("CPRP2P SZx", CompressorKind::Szx),
        ("CPRP2P ZFP(ABS)", CompressorKind::ZfpAbs),
        ("CPRP2P ZFP(FXR)", CompressorKind::ZfpFixedRate),
    ];
    t.row(vec!["MPI".into(), "1.00".into(), "0".into(), "100".into(), "0".into(), "1.0".into()]);
    for (label, kind) in variants {
        let ratio = sample_ratio(kind, FieldKind::Rtm, ErrorBound::Rel(1e-4), 1 << 18, 17);
        let r = sim_allreduce(
            &SimParams {
                n: 64,
                bytes: 600e6,
                algo: Algo::Cprp2p,
                kind,
                multithread: false,
                ratio,
            },
            &cm,
        );
        let tot = r.breakdown.total_s();
        t.row(vec![
            label.into(),
            format!("{:.2}", r.makespan_s / mpi.makespan_s),
            format!("{:.0}", (r.breakdown.compress_s + r.breakdown.decompress_s) / tot * 100.0),
            format!("{:.0}", r.breakdown.comm_s / tot * 100.0),
            format!("{:.0}", r.breakdown.compute_s / tot * 100.0),
            format!("{:.1}", ratio),
        ]);
    }
    vec![("fig9-cprp2p-baselines".into(), t)]
}

/// Fig. 10: Allgather, CPRP2P vs ZCCL across sizes (64 nodes).
fn fig10() -> Vec<(String, Table)> {
    sim_mode_rows(
        "fig10-allgather",
        &[50.0, 100.0, 150.0, 200.0, 250.0, 300.0, 400.0, 500.0, 600.0],
        64,
        &[
            ("MPI", Algo::Plain, CompressorKind::FzLight, false),
            ("CPRP2P", Algo::Cprp2p, CompressorKind::FzLight, false),
            ("ZCCL", Algo::Zccl, CompressorKind::FzLight, false),
        ],
        sim_allgather,
    )
}

/// Fig. 11: Reduce-scatter communication time, CPRP2P vs ZCCL(PIPE).
fn fig11() -> Vec<(String, Table)> {
    let cm = CostModel::paper_broadwell();
    let mut t = Table::new(&["mode", "size MB", "comm s", "total s"]);
    for mb in [50.0, 100.0, 150.0, 200.0, 250.0, 300.0, 400.0, 500.0, 600.0] {
        for (label, algo) in [("CPRP2P", Algo::Cprp2p), ("ZCCL(PIPE)", Algo::Zccl)] {
            let ratio = sample_ratio(
                CompressorKind::FzLight,
                FieldKind::Rtm,
                ErrorBound::Rel(1e-4),
                1 << 18,
                17,
            );
            let p = SimParams {
                n: 64,
                bytes: mb * 1e6,
                algo,
                kind: CompressorKind::FzLight,
                multithread: false,
                ratio,
            };
            let r = sim_reduce_scatter(&p, &cm);
            t.row(vec![
                label.into(),
                format!("{mb:.0}"),
                format!("{:.4}", r.breakdown.comm_s),
                format!("{:.4}", r.makespan_s),
            ]);
        }
    }
    vec![("fig11-reduce-scatter-comm".into(), t)]
}

/// Fig. 12: Z-Allreduce vs all baselines across sizes (64 nodes).
fn fig12() -> Vec<(String, Table)> {
    sim_mode_rows(
        "fig12-allreduce",
        &[50.0, 150.0, 300.0, 450.0, 600.0],
        64,
        &[
            ("MPI", Algo::Plain, CompressorKind::FzLight, false),
            ("CPRP2P", Algo::Cprp2p, CompressorKind::FzLight, false),
            ("C-Coll", Algo::CColl, CompressorKind::Szx, false),
            ("ZCCL-1T", Algo::Zccl, CompressorKind::FzLight, false),
            ("ZCCL-MT", Algo::Zccl, CompressorKind::FzLight, true),
        ],
        sim_allreduce,
    )
}

/// Fig. 13: node scaling at fixed 678 MB.
fn fig13() -> Vec<(String, Table)> {
    let mut out = Vec::new();
    for n in [2usize, 4, 8, 16, 32, 64, 128] {
        let mut v = sim_mode_rows(
            "fig13-scaling",
            &[678.0],
            n,
            &[
                ("MPI", Algo::Plain, CompressorKind::FzLight, false),
                ("CPRP2P", Algo::Cprp2p, CompressorKind::FzLight, false),
                ("C-Coll", Algo::CColl, CompressorKind::Szx, false),
                ("ZCCL-1T", Algo::Zccl, CompressorKind::FzLight, false),
                ("ZCCL-MT", Algo::Zccl, CompressorKind::FzLight, true),
            ],
            sim_allreduce,
        );
        out.append(&mut v);
    }
    // Merge the per-n tables into one.
    let mut merged = Table::new(&[
        "mode", "size MB", "nodes", "time s", "speedup-vs-MPI", "compress s", "comm s",
    ]);
    for (_, t) in out {
        for row in t_rows(&t) {
            merged.row(row);
        }
    }
    vec![("fig13-scaling".into(), merged)]
}

/// Figs. 14–15: binomial-tree collectives (bcast/scatter) speedups.
fn fig_tree(
    name: &str,
    simfn: fn(&SimParams, &CostModel) -> crate::sim::SimReport,
) -> Vec<(String, Table)> {
    sim_mode_rows(
        name,
        &[50.0, 150.0, 300.0, 450.0, 600.0],
        64,
        &[
            ("MPI", Algo::Plain, CompressorKind::FzLight, false),
            ("C-Coll", Algo::CColl, CompressorKind::Szx, false),
            ("ZCCL-1T", Algo::Zccl, CompressorKind::FzLight, false),
            ("ZCCL-MT", Algo::Zccl, CompressorKind::FzLight, true),
        ],
        simfn,
    )
}

/// Table 7 + Fig. 16: REAL image-stacking runs across modes, with phase
/// breakdowns, accuracy, and PGM dumps.
fn table7(out_dir: &Path) -> Result<Vec<(String, Table)>> {
    let (ranks, imgs, rows, cols) = (8usize, 3usize, 256usize, 320usize);
    let eb = ErrorBound::Rel(1e-4);
    let mut t = Table::new(&[
        "solution", "speedup", "compress %", "comm %", "compute %", "other %", "PSNR dB",
        "NRMSE",
    ]);
    let mut plain_time = None;
    let runs: Vec<(&str, Mode)> = vec![
        ("MPI (plain)", Mode::plain()),
        ("CPRP2P", Mode::cprp2p(CompressorKind::FzLight, eb)),
        ("C-Coll", Mode::ccoll(eb)),
        ("ZCCL (single-thread)", Mode::zccl(CompressorKind::FzLight, eb)),
        ("ZCCL (multi-thread)", Mode::zccl(CompressorKind::FzLight, eb).with_multithread(true)),
    ];
    for (label, mode) in runs {
        let r = image_stacking::run(ranks, imgs, rows, cols, mode, 77)?;
        if plain_time.is_none() {
            plain_time = Some(r.wall_s);
        }
        let (c, comm, compute, other) = r.metrics.breakdown_pct();
        t.row(vec![
            label.into(),
            format!("{:.2}", plain_time.unwrap() / r.wall_s),
            format!("{c:.1}"),
            format!("{comm:.1}"),
            format!("{compute:.1}"),
            format!("{other:.1}"),
            format!("{:.1}", r.quality.psnr),
            format!("{:.1e}", r.quality.nrmse),
        ]);
        if label.starts_with("ZCCL (single") {
            visualize::write_pgm(out_dir.join("fig16-zccl.pgm"), &r.image, rows, cols)?;
        }
        if label.starts_with("MPI") {
            visualize::write_pgm(out_dir.join("fig16-mpi.pgm"), &r.image, rows, cols)?;
        }
    }
    Ok(vec![("table7-image-stacking".into(), t)])
}

/// Simulator cross-check: real in-process runs vs simulated makespans at
/// small scale using the locally-calibrated cost model. We compare the
/// *ordering* and rough magnitude, not exact times (the in-process
/// "network" is a memcpy).
fn crosscheck() -> Vec<(String, Table)> {
    let cm = crate::sim::calibrate::local_model(0.05);
    let mut t = Table::new(&["collective", "mode", "ranks", "real s", "sim s (local model)"]);
    let n = 4;
    let values = 1 << 20;
    for (label, mode, algo) in [
        ("allreduce", Mode::plain(), Algo::Plain),
        (
            "allreduce",
            Mode::zccl(CompressorKind::FzLight, ErrorBound::Rel(1e-4)),
            Algo::Zccl,
        ),
        (
            "allreduce",
            Mode::cprp2p(CompressorKind::FzLight, ErrorBound::Rel(1e-4)),
            Algo::Cprp2p,
        ),
    ] {
        let out = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let f = Field::generate(FieldKind::Rtm, values, 5 + ctx.rank() as u64);
            let t0 = std::time::Instant::now();
            ctx.allreduce(&f.values, ReduceOp::Sum).unwrap();
            t0.elapsed().as_secs_f64()
        });
        let real = out.iter().cloned().fold(0.0, f64::max);
        let ratio = sample_ratio(
            CompressorKind::FzLight,
            FieldKind::Rtm,
            ErrorBound::Rel(1e-4),
            1 << 18,
            5,
        );
        let sim = sim_allreduce(
            &SimParams {
                n,
                bytes: (values * 4) as f64,
                algo,
                kind: CompressorKind::FzLight,
                multithread: false,
                ratio,
            },
            &cm,
        );
        t.row(vec![
            label.into(),
            format!("{:?}", algo),
            format!("{n}"),
            format!("{real:.4}"),
            format!("{:.4}", sim.makespan_s),
        ]);
    }
    vec![("crosscheck-sim-vs-real".into(), t)]
}

/// `zccl bench hier` — the hierarchical tier, four tables plus the
/// single-line `BENCH_hier.json` summary:
///
/// 1. REAL flat-vs-hier allreduce over a node-partitioned 4×4 in-process
///    fabric (wall time, bytes crossing the slow tier, leader/follower
///    compress counts).
/// 2. Pipelined vs monolithic inter-leader transfers: the hier allgather
///    ring with its §3.5.1 segment forced monolithic, at the
///    [`crate::sim::calibrate::pick_segment_bytes`] choice, and at a
///    deliberately tiny 4 KiB (maximum overlap, maximum per-segment
///    overhead).
/// 3. Intra-tier mode rows: the same hier allreduce with the fast tier
///    raw vs compressed ([`CollCtx::set_intra_mode`]), with per-tier byte
///    and intra-compress counters.
/// 4. The per-tier simulator sweeping ranks-per-node at cluster scale
///    with the calibrated flat-vs-hier picker.
///
/// Exposed as a library function so a tier-1 test can run it on a tiny
/// budget and assert the JSON contract.
pub fn hier_bench(budget_s: f64) -> (Vec<(String, Table)>, Json) {
    let mut t = Table::new(&[
        "schedule", "ranks", "wall s", "slow-tier MB", "leader compresses",
        "follower compresses",
    ]);
    let topo = Topology::blocked(4, 4);
    // Tiny budgets (the tier-1 contract test) shrink the payloads; the
    // row set and JSON shape stay identical.
    let values = if budget_s < 0.01 { 1 << 12 } else { 1 << 18 };
    let eb = ErrorBound::Rel(1e-4);
    let mut flat_wall = 0.0f64;
    let mut hier_wall = 0.0f64;
    let mut hier_slow_mb = 0.0f64;
    for (label, mode) in [
        ("flat zccl", Mode::zccl(CompressorKind::FzLight, eb)),
        ("hier 4x4", Mode::hier(CompressorKind::FzLight, eb)),
    ] {
        let t2 = topo.clone();
        let (out, report) = run_ranks_on(&topo, move |c| {
            let mut ctx = CollCtx::over_nodes(c, mode, t2.clone()).unwrap();
            let f = Field::generate(FieldKind::Rtm, values, 11 + ctx.rank() as u64);
            let t0 = std::time::Instant::now();
            ctx.allreduce(&f.values, ReduceOp::Sum).unwrap();
            (t0.elapsed().as_secs_f64(), ctx.compress_calls())
        });
        let wall = out.iter().map(|x| x.0).fold(0.0, f64::max);
        if mode.algo == Algo::Hier {
            hier_wall = wall;
            hier_slow_mb = report.tier.inter_bytes as f64 / 1e6;
        } else {
            flat_wall = wall;
        }
        let leader: u64 = out
            .iter()
            .enumerate()
            .filter(|(r, _)| topo.is_leader(*r))
            .map(|(_, x)| x.1)
            .sum();
        let follower: u64 = out
            .iter()
            .enumerate()
            .filter(|(r, _)| !topo.is_leader(*r))
            .map(|(_, x)| x.1)
            .sum();
        t.row(vec![
            label.into(),
            format!("{}", topo.ranks()),
            format!("{wall:.4}"),
            format!("{:.2}", report.tier.inter_bytes as f64 / 1e6),
            format!("{leader}"),
            format!("{follower}"),
        ]);
    }

    // Pipelined vs monolithic inter-leader transfers: hier allgather over
    // 2 nodes × 4 ranks, where each ring round ships one node's bundle.
    let cm = CostModel::paper_broadwell();
    let ptopo = Topology::blocked(2, 4);
    let pvalues = if budget_s < 0.01 { 1 << 12 } else { 1 << 16 };
    let iters = ((budget_s / 0.02).ceil() as usize).clamp(1, 8);
    let bundle_raw = (4 * pvalues * 4) as f64; // one node's worth, pre-compression
    let picked = crate::sim::calibrate::pick_segment_bytes(bundle_raw, &cm, false);
    let mut pt = Table::new(&["segment", "bytes", "allgather wall s", "slow-tier MB"]);
    let mut pipeline_rows = Vec::new();
    for (label, seg) in [
        ("monolithic", usize::MAX),
        ("picked", picked),
        ("fine-4k", 1usize << 12),
    ] {
        let mode = Mode::hier(CompressorKind::FzLight, eb).with_pipeline_bytes(seg);
        let t2 = ptopo.clone();
        let (out, report) = run_ranks_on(&ptopo, move |c| {
            let mut ctx = CollCtx::over_nodes(c, mode, t2.clone()).unwrap();
            let f = Field::generate(FieldKind::Rtm, pvalues, 29 + ctx.rank() as u64);
            ctx.allgather(&f.values).unwrap(); // warm: pools + codec
            let t0 = std::time::Instant::now();
            for _ in 0..iters {
                ctx.allgather(&f.values).unwrap();
            }
            t0.elapsed().as_secs_f64() / iters as f64
        });
        let wall = out.iter().cloned().fold(0.0, f64::max);
        pt.row(vec![
            label.into(),
            if seg == usize::MAX { "-".into() } else { format!("{seg}") },
            format!("{wall:.5}"),
            format!("{:.2}", report.tier.inter_bytes as f64 / 1e6),
        ]);
        pipeline_rows.push(Json::obj(vec![
            ("segment", Json::Str(label.into())),
            ("segment_bytes", Json::Num(if seg == usize::MAX { 0.0 } else { seg as f64 })),
            ("wall_s", Json::Num(wall)),
        ]));
    }

    // Intra-tier mode: the same hier allreduce with the fast tier raw vs
    // carrying compressed frames (compress-once-per-hop).
    let mut it = Table::new(&[
        "intra tier", "wall s", "intra compresses", "slow-tier MB", "fast-tier MB",
    ]);
    let mut intra_rows = Vec::new();
    for (label, compressed) in [("raw", false), ("compressed", true)] {
        let mode = Mode::hier(CompressorKind::FzLight, eb);
        let t2 = ptopo.clone();
        let (out, report) = run_ranks_on(&ptopo, move |c| {
            let mut ctx = CollCtx::over_nodes(c, mode, t2.clone()).unwrap();
            if compressed {
                ctx.set_intra_mode(Mode::zccl(CompressorKind::FzLight, eb)).unwrap();
            }
            let f = Field::generate(FieldKind::Rtm, pvalues, 43 + ctx.rank() as u64);
            ctx.allreduce(&f.values, ReduceOp::Sum).unwrap(); // warm
            let t0 = std::time::Instant::now();
            for _ in 0..iters {
                ctx.allreduce(&f.values, ReduceOp::Sum).unwrap();
            }
            (t0.elapsed().as_secs_f64() / iters as f64, ctx.intra_compress_calls())
        });
        let wall = out.iter().map(|x| x.0).fold(0.0, f64::max);
        let calls: u64 = out.iter().map(|x| x.1).sum();
        it.row(vec![
            label.into(),
            format!("{wall:.5}"),
            format!("{calls}"),
            format!("{:.2}", report.tier.inter_bytes as f64 / 1e6),
            format!("{:.2}", report.tier.intra_bytes as f64 / 1e6),
        ]);
        intra_rows.push(Json::obj(vec![
            ("intra", Json::Str(label.into())),
            ("wall_s", Json::Num(wall)),
            ("intra_compress_calls", Json::Num(calls as f64)),
            ("inter_mb", Json::Num(report.tier.inter_bytes as f64 / 1e6)),
            ("intra_mb", Json::Num(report.tier.intra_bytes as f64 / 1e6)),
        ]));
    }
    // Per-tier simulator: where does the hierarchy start paying at
    // cluster scale?
    let mut sim_t =
        Table::new(&["total ranks", "ranks/node", "hier s", "flat s", "picker"]);
    let ratio = sample_ratio(
        CompressorKind::FzLight,
        FieldKind::Rtm,
        ErrorBound::Rel(1e-4),
        1 << 18,
        17,
    );
    for rpn in [1usize, 4, 8, 16] {
        let p = SimParams {
            n: 512,
            bytes: 300e6,
            algo: Algo::Zccl,
            kind: CompressorKind::FzLight,
            multithread: false,
            ratio,
        };
        let flat = sim_allreduce(&p, &cm);
        let hier = sim_allreduce_hier(&p, rpn, &cm);
        let pick = pick_allreduce_algo(&p, rpn, &cm);
        sim_t.row(vec![
            "512".into(),
            format!("{rpn}"),
            format!("{:.4}", hier.makespan_s),
            format!("{:.4}", flat.makespan_s),
            format!("{pick:?}"),
        ]);
    }
    let summary = Json::obj(vec![
        ("bench", Json::Str("hier".into())),
        ("budget_s", Json::Num(budget_s)),
        ("flat_wall_s", Json::Num(flat_wall)),
        ("hier_wall_s", Json::Num(hier_wall)),
        ("hier_slow_tier_mb", Json::Num(hier_slow_mb)),
        ("picked_segment_bytes", Json::Num(picked as f64)),
        ("pipeline", Json::Arr(pipeline_rows)),
        ("intra", Json::Arr(intra_rows)),
    ]);
    (
        vec![
            ("hier-real-4x4".into(), t),
            ("hier-pipeline".into(), pt),
            ("hier-intra-mode".into(), it),
            ("hier-sim-scaling".into(), sim_t),
        ],
        summary,
    )
}

/// `zccl bench codec` — word-parallel codec kernel throughput. Four
/// tables: end-to-end comp/decomp GB/s per codec × dataset × REL bound
/// (the bit-shifting codecs, single-thread); the raw
/// [`bits::pack_fixed`] / [`bits::unpack_fixed`] kernels against the
/// retained scalar [`bits::BitWriter`] / [`bits::BitReader`] reference
/// path across code widths; per-stage GB/s for the staged pipeline
/// (quantize / pack / entropy, encode + decode); and adaptive staged
/// frames vs fixed-width on synthetic low- and high-entropy datasets
/// (`staged` JSON rows — ratio regressions in either direction fail the
/// tier-1 contract test). Returns the tables plus the single-line
/// `BENCH_codec.json` summary whose `speedup_vs_reference` field tracks
/// the word-parallel kernels' edge from PR to PR. Exposed as a library
/// function so a tier-1 test can run it on a tiny budget and assert the
/// JSON contract.
pub fn codec_bench(values: usize, budget_s: f64) -> (Vec<(String, Table)>, Json) {
    let mut t = Table::new(&["codec", "dataset", "rel", "comp GB/s", "decomp GB/s", "ratio"]);
    let mut codec_rows: Vec<Json> = Vec::new();
    for kind in [CompressorKind::FzLight, CompressorKind::Szx] {
        for fk in [FieldKind::Rtm, FieldKind::Nyx] {
            let f = Field::generate(fk, values, 42);
            let bytes = values * 4;
            for rel in [1e-2, 1e-4] {
                let codec = compress::build(kind);
                let eb = ErrorBound::Rel(rel);
                let frame = codec.compress(&f.values, eb).expect("compress");
                let mut buf = Vec::with_capacity(frame.bytes.len());
                let c = measure_for(budget_s, || {
                    buf.clear();
                    codec.compress_into(&f.values, eb, &mut buf).unwrap()
                });
                let mut dst: Vec<f32> = Vec::with_capacity(values);
                let d = measure_for(budget_s, || {
                    dst.clear();
                    codec.decompress_into(&frame.bytes, &mut dst).unwrap()
                });
                t.row(vec![
                    kind.name().into(),
                    fk.name().into(),
                    format!("{rel:.0e}"),
                    format!("{:.3}", c.gbps(bytes)),
                    format!("{:.3}", d.gbps(bytes)),
                    format!("{:.2}", frame.stats.ratio()),
                ]);
                codec_rows.push(Json::obj(vec![
                    ("codec", Json::Str(kind.name().into())),
                    ("dataset", Json::Str(fk.name().into())),
                    ("rel", Json::Num(rel)),
                    ("comp_gbps", Json::Num(c.gbps(bytes))),
                    ("decomp_gbps", Json::Num(d.gbps(bytes))),
                    ("ratio", Json::Num(frame.stats.ratio())),
                ]));
            }
        }
    }

    // Raw bit-kernel section: the same code stream packed/unpacked by the
    // word-parallel kernels and by the scalar reference, per width class
    // (incl. the 58..=64 two-limb path). Throughput is u64 codes
    // processed (8 bytes per code).
    let mut kt = Table::new(&[
        "width", "pack GB/s", "pack ref GB/s", "unpack GB/s", "unpack ref GB/s",
    ]);
    let mut rng = Rng::new(7);
    let codes = (values / 8).max(1024);
    let code_bytes = codes * 8;
    let mut kernel_s = 0.0f64;
    let mut reference_s = 0.0f64;
    for width in [2u32, 7, 13, 26, 57, 64] {
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        let vals: Vec<u64> = (0..codes).map(|_| rng.next_u64() & mask).collect();
        let mut buf = Vec::new();
        let pk = measure_for(budget_s, || {
            buf.clear();
            bits::pack_fixed(&mut buf, &vals, width);
        });
        let mut rbuf = Vec::new();
        let pr = measure_for(budget_s, || {
            rbuf.clear();
            bits::pack_fixed_reference(&mut rbuf, &vals, width);
        });
        buf.clear();
        bits::pack_fixed(&mut buf, &vals, width);
        let mut out = vec![0u64; codes];
        let uk = measure_for(budget_s, || bits::unpack_fixed(&buf, width, &mut out));
        let ur = measure_for(budget_s, || bits::unpack_fixed_reference(&buf, width, &mut out));
        kernel_s += pk.mean_s + uk.mean_s;
        reference_s += pr.mean_s + ur.mean_s;
        kt.row(vec![
            format!("{width}"),
            format!("{:.3}", pk.gbps(code_bytes)),
            format!("{:.3}", pr.gbps(code_bytes)),
            format!("{:.3}", uk.gbps(code_bytes)),
            format!("{:.3}", ur.gbps(code_bytes)),
        ]);
    }
    let speedup = reference_s / kernel_s.max(1e-12);

    // Per-stage throughput for the staged pipeline on the smooth Rtm
    // field: quantize (round-to-i64 + dequantize multiply), pack (the
    // full fixed-width frame encode/decode around it), entropy (the
    // order-0 rANS coder over the packed frame bytes).
    let stage_f = Field::generate(FieldKind::Rtm, values, 42);
    let raw_bytes = values * 4;
    let eb_abs = ErrorBound::Rel(1e-3).resolve(&stage_f.values);
    let inv = 1.0 / (2.0 * eb_abs);
    let twoeb = 2.0 * eb_abs;
    let mut qbuf: Vec<i64> = Vec::with_capacity(values);
    let q_enc = measure_for(budget_s, || {
        qbuf.clear();
        qbuf.extend(stage_f.values.iter().map(|&x| (x as f64 * inv).round() as i64));
    });
    let mut fbuf = vec![0.0f32; values];
    let q_dec = measure_for(budget_s, || {
        for (o, &q) in fbuf.iter_mut().zip(&qbuf) {
            *o = (q as f64 * twoeb) as f32;
        }
    });
    let fz = compress::FzLight::default();
    let v1 = fz.compress(&stage_f.values, ErrorBound::Abs(eb_abs)).expect("compress");
    let mut frame = Vec::with_capacity(v1.bytes.len());
    let p_enc = measure_for(budget_s, || {
        frame.clear();
        fz.compress_into(&stage_f.values, ErrorBound::Abs(eb_abs), &mut frame).unwrap()
    });
    let mut dst: Vec<f32> = Vec::with_capacity(values);
    let p_dec = measure_for(budget_s, || {
        dst.clear();
        fz.decompress_into(&v1.bytes, &mut dst).unwrap()
    });
    let mut blob = Vec::new();
    let e_enc = measure_for(budget_s, || {
        blob.clear();
        compress::entropy::encode(&v1.bytes, &mut blob);
    });
    let mut raw = Vec::with_capacity(v1.bytes.len());
    let e_dec = measure_for(budget_s, || {
        raw.clear();
        compress::entropy::decode(&blob, v1.bytes.len(), &mut raw).unwrap();
    });
    let mut st = Table::new(&["stage", "enc GB/s", "dec GB/s"]);
    let mut stage_rows = Vec::new();
    for (name, enc, dec, bytes) in [
        ("quantize", &q_enc, &q_dec, raw_bytes),
        ("pack", &p_enc, &p_dec, raw_bytes),
        ("entropy", &e_enc, &e_dec, v1.bytes.len()),
    ] {
        st.row(vec![
            name.into(),
            format!("{:.3}", enc.gbps(bytes)),
            format!("{:.3}", dec.gbps(bytes)),
        ]);
        stage_rows.push(Json::obj(vec![
            ("stage", Json::Str(name.into())),
            ("enc_gbps", Json::Num(enc.gbps(bytes))),
            ("dec_gbps", Json::Num(dec.gbps(bytes))),
        ]));
    }

    // Adaptive staged frames vs fixed-width on synthetic extremes: a
    // plateau staircase (wide constant runs — the entropy stage's best
    // case) and a uniform-16-bit-delta random walk (worst case — the
    // selector must fall back to fixed-width, costing at most the
    // per-chunk stage tag). The ratios are deterministic; the tier-1
    // contract test pins the gain floor and the never-worse bound.
    let mut sdt = Table::new(&[
        "dataset", "fixed ratio", "staged ratio", "gain", "enc GB/s", "dec GB/s", "e/p chunks",
    ]);
    let mut staged_rows = Vec::new();
    let low: Vec<f32> = (0..values).map(|i| (i / 512) as f32).collect();
    let mut walk_rng = Rng::new(11);
    let mut walk = 0.0f32;
    let high: Vec<f32> = (0..values)
        .map(|_| {
            walk += (walk_rng.below(1 << 16) as f32 - 32_768.0) * 1e-3;
            walk
        })
        .collect();
    for (name, data) in [("low-entropy", &low), ("high-entropy", &high)] {
        let eb = ErrorBound::Abs(1e-3);
        let fixed = compress::FzLight::default().compress(data, eb).expect("compress");
        let codec = compress::FzLight::default().with_staged(true);
        let staged = codec.compress(data, eb).expect("compress");
        let mut buf = Vec::with_capacity(staged.bytes.len());
        let s_enc = measure_for(budget_s, || {
            buf.clear();
            codec.compress_into(data, eb, &mut buf).unwrap()
        });
        let mut out: Vec<f32> = Vec::with_capacity(data.len());
        let s_dec = measure_for(budget_s, || {
            out.clear();
            codec.decompress_into(&staged.bytes, &mut out).unwrap()
        });
        let gain = fixed.bytes.len() as f64 / staged.bytes.len() as f64;
        sdt.row(vec![
            name.into(),
            format!("{:.2}", fixed.stats.ratio()),
            format!("{:.2}", staged.stats.ratio()),
            format!("{gain:.3}"),
            format!("{:.3}", s_enc.gbps(raw_bytes)),
            format!("{:.3}", s_dec.gbps(raw_bytes)),
            format!("{}/{}", staged.stats.entropy_chunks, staged.stats.plain_chunks),
        ]);
        staged_rows.push(Json::obj(vec![
            ("dataset", Json::Str(name.into())),
            ("fixed_ratio", Json::Num(fixed.stats.ratio())),
            ("staged_ratio", Json::Num(staged.stats.ratio())),
            ("gain", Json::Num(gain)),
            ("comp_gbps", Json::Num(s_enc.gbps(raw_bytes))),
            ("decomp_gbps", Json::Num(s_dec.gbps(raw_bytes))),
            ("fixed_bytes", Json::Num(fixed.bytes.len() as f64)),
            ("staged_bytes", Json::Num(staged.bytes.len() as f64)),
            ("chunks", Json::Num(staged.stats.chunks as f64)),
            ("entropy_chunks", Json::Num(staged.stats.entropy_chunks as f64)),
            ("plain_chunks", Json::Num(staged.stats.plain_chunks as f64)),
        ]));
    }

    let summary = Json::obj(vec![
        ("bench", Json::Str("codec_kernels".into())),
        ("values", Json::Num(values as f64)),
        ("budget_s", Json::Num(budget_s)),
        ("codecs", Json::Arr(codec_rows)),
        ("stages", Json::Arr(stage_rows)),
        ("staged", Json::Arr(staged_rows)),
        ("kernel_pack_unpack_s", Json::Num(kernel_s)),
        ("reference_pack_unpack_s", Json::Num(reference_s)),
        ("speedup_vs_reference", Json::Num(speedup)),
    ]);
    (
        vec![
            ("codec-throughput".into(), t),
            ("codec-bit-kernels".into(), kt),
            ("codec-stages".into(), st),
            ("codec-staged".into(), sdt),
        ],
        summary,
    )
}

/// Synthetic compute: a serially-dependent float chain the optimiser
/// cannot elide (the seed and result both pass through `black_box`).
fn spin(mut acc: f32, iters: usize) -> f32 {
    for i in 0..iters {
        acc += std::hint::black_box(i as f32).sqrt();
    }
    std::hint::black_box(acc)
}

/// `zccl bench overlap` — REAL bucketed nonblocking allreduce overlapped
/// with synthetic compute, against the blocking bucket-by-bucket baseline
/// on the same inputs (4 ranks over the in-process fabric, ZCCL
/// fZ-light). The nonblocking path mirrors the DDP bucketed schedule:
/// each bucket's `iallreduce` launches as soon as its "gradients" are
/// computed, `test()` polls between compute slices drive the in-flight
/// requests, and only the final `wait`s block. Emits the single-line
/// `BENCH_overlap.json` whose `exposed_comm_s` is the nonblocking path's
/// blocked time per step — the overlap-win contract is that it sits
/// below `blocking_allreduce_s`. Exposed as a library function so a
/// tier-1 test can run it on a tiny budget and assert the JSON contract.
pub fn overlap_bench(budget_s: f64) -> (Vec<(String, Table)>, Json) {
    const RANKS: usize = 4;
    const BUCKETS: usize = 4;
    const VALUES: usize = 1 << 16; // per bucket
    const SPIN: usize = 1 << 15; // synthetic compute per bucket
    const SLICE: usize = 1 << 11; // compute granule between test() polls
    // SPMD-safe budget: every rank must agree on the iteration count, so
    // it is derived from the budget before spawning, not measured inside.
    let iters = ((budget_s / 0.01).ceil() as usize).clamp(1, 64);
    let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Rel(1e-4));
    let out = run_ranks(RANKS, move |c| {
        let mut ctx = CollCtx::over(c, mode);
        let inputs: Vec<Vec<f32>> = (0..BUCKETS)
            .map(|b| {
                let seed = 23 + (b * RANKS + ctx.rank()) as u64;
                Field::generate(FieldKind::Rtm, VALUES, seed).values
            })
            .collect();
        let mut avg: Vec<f32> = Vec::new();
        let mut acc = 0.0f32;
        // Warm both paths once: codec built, buffer pools populated.
        ctx.allreduce_into(&inputs[0], ReduceOp::Sum, &mut avg).unwrap();
        let req = ctx.iallreduce(&inputs[0], ReduceOp::Sum).unwrap();
        ctx.wait_into(req, &mut avg).unwrap();
        let _ = ctx.take_metrics();

        let mut blocking_s = 0.0f64;
        let mut blocking_comm_s = 0.0f64;
        let mut nonblocking_s = 0.0f64;
        for _ in 0..iters {
            // Blocking baseline: compute a bucket, then block on its
            // allreduce — nothing overlaps.
            let t0 = std::time::Instant::now();
            for input in &inputs {
                acc = spin(acc, SPIN);
                let t1 = std::time::Instant::now();
                ctx.allreduce_into(input, ReduceOp::Sum, &mut avg).unwrap();
                blocking_comm_s += t1.elapsed().as_secs_f64();
            }
            blocking_s += t0.elapsed().as_secs_f64();
            // Nonblocking: launch each bucket as it becomes ready and
            // hide its progress behind the remaining buckets' compute.
            let t0 = std::time::Instant::now();
            let mut reqs = Vec::with_capacity(BUCKETS);
            for input in &inputs {
                let mut done = 0;
                while done < SPIN {
                    acc = spin(acc, SLICE);
                    done += SLICE;
                    if let Some(first) = reqs.first() {
                        ctx.test(first).unwrap(); // drives every request
                    }
                }
                reqs.push(ctx.iallreduce(input, ReduceOp::Sum).unwrap());
            }
            for req in reqs {
                ctx.wait_into(req, &mut avg).unwrap();
            }
            nonblocking_s += t0.elapsed().as_secs_f64();
        }
        let m = ctx.take_metrics();
        std::hint::black_box(acc);
        (blocking_s, blocking_comm_s, nonblocking_s, m.exposed_comm_s, m.hidden_comm_s)
    });
    // Critical path: the slowest rank on each measure.
    let blocking_s = out.iter().map(|x| x.0).fold(0.0, f64::max);
    let blocking_comm_s = out.iter().map(|x| x.1).fold(0.0, f64::max);
    let nonblocking_s = out.iter().map(|x| x.2).fold(0.0, f64::max);
    let exposed_s = out.iter().map(|x| x.3).fold(0.0, f64::max);
    let hidden_s = out.iter().map(|x| x.4).fold(0.0, f64::max);
    let iters_f = iters as f64;
    let elems = iters_f * (BUCKETS * VALUES) as f64;
    let hidden_fraction = hidden_s / (hidden_s + exposed_s).max(1e-12);

    let mut t = Table::new(&["path", "step s", "blocked-on-comm s", "ns/element", "hidden frac"]);
    t.row(vec![
        "blocking".into(),
        format!("{:.5}", blocking_s / iters_f),
        format!("{:.5}", blocking_comm_s / iters_f),
        format!("{:.1}", blocking_s / elems * 1e9),
        "0.00".into(),
    ]);
    t.row(vec![
        "nonblocking".into(),
        format!("{:.5}", nonblocking_s / iters_f),
        format!("{:.5}", exposed_s / iters_f),
        format!("{:.1}", nonblocking_s / elems * 1e9),
        format!("{hidden_fraction:.2}"),
    ]);
    let summary = Json::obj(vec![
        ("bench", Json::Str("overlap".into())),
        ("ranks", Json::Num(RANKS as f64)),
        ("buckets", Json::Num(BUCKETS as f64)),
        ("values_per_bucket", Json::Num(VALUES as f64)),
        ("iters", Json::Num(iters_f)),
        ("blocking_ns_per_element", Json::Num(blocking_s / elems * 1e9)),
        ("nonblocking_ns_per_element", Json::Num(nonblocking_s / elems * 1e9)),
        ("blocking_allreduce_s", Json::Num(blocking_comm_s / iters_f)),
        ("exposed_comm_s", Json::Num(exposed_s / iters_f)),
        ("hidden_fraction", Json::Num(hidden_fraction)),
    ]);
    (vec![("overlap-allreduce".into(), t)], summary)
}

/// One dead-peer detection sample: a 4-rank ZCCL allreduce over the
/// fault-wrapped in-process fabric with rank 1 killed after its second
/// ring send. Returns the slowest *survivor*'s time-to-error — the
/// latency between a peer dying mid-collective and every other rank
/// holding a typed failure.
fn dead_peer_sample(timeout: Duration) -> f64 {
    const RANKS: usize = 4;
    const KILLED: usize = 1;
    let handles: Vec<_> = MemFabric::endpoints(RANKS)
        .into_iter()
        .enumerate()
        .map(|(r, t)| {
            let plan = if r == KILLED {
                FaultPlan::new(7).kill_after(2)
            } else {
                FaultPlan::new(7 ^ r as u64)
            };
            std::thread::spawn(move || {
                let mut ft = FaultTransport::new(t, plan);
                let mut comm = Communicator::new(&mut ft);
                let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-3));
                let mut ctx = CollCtx::over(&mut comm, mode);
                ctx.set_timeout(Some(timeout));
                let x: Vec<f32> = (0..4096).map(|i| (i as f32 * 0.01).sin()).collect();
                let t0 = std::time::Instant::now();
                let failed = ctx.allreduce(&x, ReduceOp::Sum).is_err();
                (failed, t0.elapsed().as_secs_f64())
            })
        })
        .collect();
    let out: Vec<(bool, f64)> =
        handles.into_iter().map(|h| h.join().expect("bench rank panicked")).collect();
    out.iter()
        .enumerate()
        .filter(|&(r, &(failed, _))| r != KILLED && failed)
        .map(|(_, &(_, s))| s)
        .fold(0.0, f64::max)
}

/// `zccl bench chaos` — failure-path costs. Two numbers, emitted as the
/// single-line `BENCH_chaos.json`: how fast a dead peer is detected (the
/// slowest survivor's time-to-error in a fault-injected 4-rank ZCCL
/// allreduce, to be read against the armed deadline), and what the wire
/// integrity layer costs (CRC32C ns/element over a 4 MiB buffer, with a
/// plain memcpy of the same bytes as the unchecked baseline). Exposed as
/// a library function so a tier-1 test can run it on a tiny budget and
/// assert the JSON contract.
pub fn chaos_bench(budget_s: f64) -> (Vec<(String, Table)>, Json) {
    // Dead-peer detection: best of three samples (scheduler noise only
    // ever inflates the number).
    let timeout = Duration::from_millis(150);
    let detect_s = (0..3).map(|_| dead_peer_sample(timeout)).fold(f64::INFINITY, f64::min);

    // Wire-integrity overhead: CRC32C vs memcpy over the same bytes.
    let values: usize = 1 << 20;
    let bytes = values * 4;
    let mut rng = Rng::new(11);
    let buf: Vec<u8> = (0..bytes).map(|_| rng.next_u64() as u8).collect();
    let crc = measure_for(budget_s, || std::hint::black_box(crc32c(&[&buf])));
    let mut dst = vec![0u8; bytes];
    let cpy = measure_for(budget_s, || dst.copy_from_slice(&buf));
    let crc_ns = crc.mean_s / values as f64 * 1e9;
    let cpy_ns = cpy.mean_s / values as f64 * 1e9;

    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["deadline ms".into(), format!("{:.0}", timeout.as_secs_f64() * 1e3)]);
    t.row(vec!["dead-peer detect ms".into(), format!("{:.1}", detect_s * 1e3)]);
    t.row(vec!["crc32c GB/s".into(), format!("{:.2}", crc.gbps(bytes))]);
    t.row(vec!["crc32c ns/element".into(), format!("{crc_ns:.3}")]);
    t.row(vec!["memcpy ns/element (unchecked)".into(), format!("{cpy_ns:.3}")]);
    let summary = Json::obj(vec![
        ("bench", Json::Str("chaos".into())),
        ("deadline_ms", Json::Num(timeout.as_secs_f64() * 1e3)),
        ("dead_peer_detect_ms", Json::Num(detect_s * 1e3)),
        ("crc_gbps", Json::Num(crc.gbps(bytes))),
        ("crc_ns_per_element", Json::Num(crc_ns)),
        ("memcpy_ns_per_element", Json::Num(cpy_ns)),
    ]);
    (vec![("chaos-failure-paths".into(), t)], summary)
}

/// Ablation: PIPE-fZ-light chunk size (paper fixes 5120).
fn ablation_chunk() -> Vec<(String, Table)> {
    let mut t = Table::new(&["pipe chunk (values)", "reduce-scatter s", "compress s"]);
    let n = 4;
    let values = 1 << 20;
    for chunk in [640usize, 1280, 2560, 5120, 10240, 20480, 81920] {
        let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Rel(1e-4))
            .with_pipe_chunk(chunk);
        let out = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let f = Field::generate(FieldKind::Rtm, values, 9 + ctx.rank() as u64);
            let t0 = std::time::Instant::now();
            ctx.reduce_scatter(&f.values, ReduceOp::Sum).unwrap();
            (t0.elapsed().as_secs_f64(), ctx.metrics().compress_s)
        });
        let wall = out.iter().map(|x| x.0).fold(0.0, f64::max);
        let comp = out.iter().map(|x| x.1).sum::<f64>() / n as f64;
        t.row(vec![format!("{chunk}"), format!("{wall:.4}"), format!("{comp:.4}")]);
    }
    vec![("ablation-chunk".into(), t)]
}

/// Ablation: balanced fixed-pipeline segment size in the Z-Allgather.
fn ablation_balance() -> Vec<(String, Table)> {
    let mut t = Table::new(&["pipeline bytes", "allgather s"]);
    let n = 4;
    let values = 1 << 19;
    for seg in [1usize << 12, 1 << 14, 1 << 16, 1 << 18, usize::MAX] {
        let mode =
            Mode::zccl(CompressorKind::FzLight, ErrorBound::Rel(1e-4)).with_pipeline_bytes(seg);
        let out = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let f = Field::generate(FieldKind::Hurricane, values, 31 + ctx.rank() as u64);
            let t0 = std::time::Instant::now();
            ctx.allgather(&f.values).unwrap();
            t0.elapsed().as_secs_f64()
        });
        let wall = out.iter().cloned().fold(0.0, f64::max);
        let label =
            if seg == usize::MAX { "unsegmented".to_string() } else { format!("{seg}") };
        t.row(vec![label, format!("{wall:.4}")]);
    }
    vec![("ablation-balance".into(), t)]
}

/// Ablation: error bound vs end-to-end time and achieved accuracy.
fn ablation_eb() -> Vec<(String, Table)> {
    let mut t = Table::new(&["rel eb", "allreduce s", "ratio", "max err / (n·eb)"]);
    let n = 4;
    let values = 1 << 19;
    for rel in [1e-1, 1e-2, 1e-3, 1e-4, 1e-5] {
        let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Rel(rel));
        let out = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let f = Field::generate(FieldKind::Cesm, values, 77 + ctx.rank() as u64);
            let t0 = std::time::Instant::now();
            let r = ctx.allreduce(&f.values, ReduceOp::Sum).unwrap();
            (t0.elapsed().as_secs_f64(), r, ctx.take_metrics())
        });
        // Exact serial reference.
        let mut exact = Field::generate(FieldKind::Cesm, values, 77).values;
        for r in 1..n {
            let f = Field::generate(FieldKind::Cesm, values, 77 + r as u64);
            for (a, v) in exact.iter_mut().zip(&f.values) {
                *a += v;
            }
        }
        let wall = out.iter().map(|x| x.0).fold(0.0, f64::max);
        let max_err = out[0]
            .1
            .iter()
            .zip(&exact)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max);
        // eb resolved against rank-0's field range (approximation).
        let eb_abs =
            ErrorBound::Rel(rel).resolve(&Field::generate(FieldKind::Cesm, values, 77).values);
        let ratio = out[0].2.raw_bytes.max(1) as f64 / out[0].2.bytes_sent.max(1) as f64;
        t.row(vec![
            format!("{rel:.0e}"),
            format!("{wall:.4}"),
            format!("{ratio:.1}"),
            format!("{:.2}", max_err / ((n as f64 + 1.0) * eb_abs)),
        ]);
    }
    vec![("ablation-eb".into(), t)]
}

/// Extract rows back out of a table (merge helper).
fn t_rows(t: &Table) -> Vec<Vec<String>> {
    // Render -> parse would be silly; Table needs an accessor. Quick CSV
    // round-trip keeps Table's API small.
    t.to_csv()
        .lines()
        .skip(1)
        .map(|l| l.split(',').map(|s| s.to_string()).collect())
        .collect()
}
