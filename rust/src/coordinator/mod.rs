//! Leader/worker orchestration, per-phase metrics, and the benchmark
//! harness dispatcher used by the `zccl` CLI.

pub mod harness;
pub mod launch;
pub mod metrics;

pub use metrics::{Metrics, Phase};
