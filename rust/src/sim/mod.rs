//! Virtual-time cost simulator.
//!
//! DESIGN.md §2: the paper's testbed (128 Broadwell nodes, 100 Gbps
//! Omni-Path) is not available — this container has one core. The
//! *algorithmic* content of the paper's figures (how many compress calls,
//! what overlaps with what, how many bytes cross links, who waits on whom)
//! is reproduced here as discrete-event models of the same schedules the
//! real implementations in [`crate::collectives`] execute. The cost
//! constants come from two sources:
//!
//! - [`CostModel::paper_broadwell`] — the paper's own measured compressor
//!   throughputs (Tables 1–2) and the Omni-Path link. Regenerates the
//!   published figure shapes.
//! - [`calibrate::local_model`] — throughputs measured on *this* host's
//!   compressors, for cross-checking the simulator against real
//!   small-scale runs.
//!
//! Compressed sizes are NOT modeled: each simulation takes real ratios
//! measured by running the actual codecs on sampled field data
//! ([`calibrate::sample_ratio`]).
//!
//! Beyond whole-collective simulations, the per-tier postal constants
//! also drive two point decisions for the hierarchical schedules:
//! [`calibrate::pick_segment_bytes`] sizes the §3.5.1 fixed pipeline
//! segment per tier (`s* = sqrt(total · α · β)`, clamped), and
//! [`calibrate::pick_intra_mode`] decides whether the fast intra-node
//! tier should carry compressed frames instead of raw `f32` hops.

pub mod calibrate;
pub mod collectives;

use crate::compress::CompressorKind;

/// Throughputs for one codec (bytes/second).
#[derive(Debug, Clone, Copy)]
pub struct CodecRate {
    /// Single-thread compression.
    pub comp_st: f64,
    /// Single-thread decompression.
    pub decomp_st: f64,
    /// Multi-thread compression.
    pub comp_mt: f64,
    /// Multi-thread decompression.
    pub decomp_mt: f64,
}

impl CodecRate {
    /// Compression bandwidth for the given thread mode.
    pub fn comp(&self, mt: bool) -> f64 {
        if mt {
            self.comp_mt
        } else {
            self.comp_st
        }
    }
    /// Decompression bandwidth for the given thread mode.
    pub fn decomp(&self, mt: bool) -> f64 {
        if mt {
            self.decomp_mt
        } else {
            self.decomp_st
        }
    }
}

/// The simulator's cost constants.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Per-message latency in seconds (α of the postal model) on the
    /// inter-node tier.
    pub alpha_s: f64,
    /// Inter-node link bandwidth in bytes/second (β⁻¹), full duplex per
    /// NIC.
    pub link_bps: f64,
    /// Per-message latency on the fast intra-node tier (shared memory /
    /// NVLink class).
    pub intra_alpha_s: f64,
    /// Intra-node bandwidth in bytes/second. The hierarchical schedules
    /// ([`crate::collectives::Algo::Hier`]) move raw data on this tier
    /// and compressed frames on the slow one; pricing the tiers
    /// separately is what lets `calibrate` pick flat vs hierarchical.
    pub intra_bps: f64,
    /// Straggler multiplier on ring-round link time when compressed chunk
    /// sizes are NOT balanced (§3.1.1: the paper measures the balanced
    /// fixed-pipeline schedule up to 1.46× faster at 600 MB; CPRP2P and
    /// C-Coll pay this, ZCCL does not).
    pub imbalance: f64,
    /// Elementwise-reduction bandwidth (bytes of operand processed /s).
    pub reduce_bps: f64,
    /// Memory copy bandwidth (packing/unpacking).
    pub copy_bps: f64,
    /// Per-codec throughputs.
    pub fzlight: CodecRate,
    pub szx: CodecRate,
    pub zfp_abs: CodecRate,
    pub zfp_fxr: CodecRate,
}

impl CostModel {
    /// Constants for the paper's testbed: dual Xeon E5-2695v4, Intel
    /// Omni-Path 100 Gbps. Compressor throughputs are the paper's Tables
    /// 1–2 (RTM column, REL 1e-4 — their default configuration), in GB/s.
    pub fn paper_broadwell() -> CostModel {
        let g = 1e9;
        CostModel {
            alpha_s: 3e-6,
            // Effective per-rank bandwidth of the MPI collective path, NOT
            // the 100 Gbps line rate. Reverse-engineered from the paper's
            // Fig. 9: CPRP2P-fZ-light (whose per-round codec cost is
            // chunk/2.61 + chunk/5.39 GB/s) roughly matches original
            // MPI_Allreduce's total time, which pins the effective
            // large-message collective bandwidth near 1.4 GB/s per rank
            // (fabric contention + MPI protocol overheads).
            link_bps: 1.4 * g,
            // The fast tier: intra-node MPI over shared memory on the
            // paper's dual-socket Broadwell runs at memory-copy class
            // bandwidth with sub-microsecond latency.
            intra_alpha_s: 4e-7,
            intra_bps: 8.0 * g,
            imbalance: 1.35,
            // One Broadwell core streams ~6 GB/s of f32 sums.
            reduce_bps: 6.0 * g,
            copy_bps: 10.0 * g,
            fzlight: CodecRate {
                comp_st: 2.61 * g,
                decomp_st: 5.39 * g,
                comp_mt: 44.09 * g,
                decomp_mt: 48.26 * g,
            },
            szx: CodecRate {
                comp_st: 3.51 * g,
                decomp_st: 6.22 * g,
                comp_mt: 26.99 * g,
                decomp_mt: 43.52 * g,
            },
            // ZFP's transform path is considerably slower (the paper cites
            // [31]); fixed-rate and fixed-accuracy behave similarly.
            zfp_abs: CodecRate {
                comp_st: 0.35 * g,
                decomp_st: 0.55 * g,
                comp_mt: 4.0 * g,
                decomp_mt: 6.0 * g,
            },
            zfp_fxr: CodecRate {
                comp_st: 0.40 * g,
                decomp_st: 0.60 * g,
                comp_mt: 4.5 * g,
                decomp_mt: 6.5 * g,
            },
        }
    }

    /// Per-codec rates.
    pub fn rate(&self, kind: CompressorKind) -> CodecRate {
        match kind {
            CompressorKind::FzLight => self.fzlight,
            CompressorKind::Szx => self.szx,
            CompressorKind::ZfpAbs => self.zfp_abs,
            CompressorKind::ZfpFixedRate => self.zfp_fxr,
        }
    }

    /// Inter-node link time for a message of `bytes`.
    #[inline]
    pub fn link_s(&self, bytes: f64) -> f64 {
        self.alpha_s + bytes / self.link_bps
    }

    /// Intra-node (fast tier) link time for a message of `bytes`.
    #[inline]
    pub fn intra_link_s(&self, bytes: f64) -> f64 {
        self.intra_alpha_s + bytes / self.intra_bps
    }
}

/// Virtual-time phase breakdown for one simulated collective (seconds on
/// the critical path, per the slowest rank).
#[derive(Debug, Clone, Copy, Default)]
pub struct SimBreakdown {
    /// Compression on the critical path.
    pub compress_s: f64,
    /// Decompression on the critical path.
    pub decompress_s: f64,
    /// Exposed (non-hidden) communication.
    pub comm_s: f64,
    /// Reduction arithmetic.
    pub compute_s: f64,
    /// Bookkeeping (size exchange etc.).
    pub other_s: f64,
}

impl SimBreakdown {
    /// Total virtual seconds.
    pub fn total_s(&self) -> f64 {
        self.compress_s + self.decompress_s + self.comm_s + self.compute_s + self.other_s
    }
}

/// Result of one simulated collective.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Completion time per rank.
    pub per_rank_s: Vec<f64>,
    /// Makespan (max over ranks).
    pub makespan_s: f64,
    /// Phase breakdown along the critical (slowest) rank.
    pub breakdown: SimBreakdown,
}

impl SimReport {
    pub(crate) fn from_ranks(per_rank_s: Vec<f64>, breakdown: SimBreakdown) -> SimReport {
        let makespan_s = per_rank_s.iter().cloned().fold(0.0, f64::max);
        SimReport { per_rank_s, makespan_s, breakdown }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_time_components() {
        let cm = CostModel::paper_broadwell();
        let t = cm.link_s(1e9);
        assert!(t > 1.0 / cm.link_bps * 1e9);
        assert!((t - cm.alpha_s - 1e9 / cm.link_bps).abs() < 1e-15);
    }

    #[test]
    fn paper_rates_sane() {
        let cm = CostModel::paper_broadwell();
        assert!(cm.fzlight.comp_mt > cm.fzlight.comp_st * 10.0);
        assert!(cm.szx.comp_st > cm.zfp_abs.comp_st);
    }

    #[test]
    fn intra_tier_is_faster() {
        let cm = CostModel::paper_broadwell();
        assert!(cm.intra_bps > cm.link_bps, "fast tier must out-run the network");
        assert!(cm.intra_alpha_s < cm.alpha_s);
        assert!(cm.intra_link_s(1e6) < cm.link_s(1e6));
    }
}
