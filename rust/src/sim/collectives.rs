//! Discrete-event models of the collective schedules.
//!
//! Each function mirrors the corresponding real implementation in
//! [`crate::collectives`] *step for step* — same rounds, same peers, same
//! compress/decompress placement — but advances per-rank virtual clocks
//! instead of moving bytes. Lockstep ring rounds propagate waiting through
//! the `max(own_ready, sender_ready)` dependency exactly like the real
//! blocking schedule.

use super::{CostModel, SimBreakdown, SimReport};
use crate::collectives::Algo;
use crate::compress::CompressorKind;
use crate::topology::{binomial_bcast, tree_rounds};

/// Inputs for one simulated collective.
#[derive(Debug, Clone, Copy)]
pub struct SimParams {
    /// Communicator size.
    pub n: usize,
    /// Uncompressed payload bytes (the collective's `D_input`).
    pub bytes: f64,
    /// Framework.
    pub algo: Algo,
    /// Codec for the compressed modes.
    pub kind: CompressorKind,
    /// Multi-thread codec mode.
    pub multithread: bool,
    /// Compression ratio (raw/compressed) measured on real data via
    /// [`super::calibrate::sample_ratio`].
    pub ratio: f64,
}

impl SimParams {
    fn cfrac(&self) -> f64 {
        if self.algo == Algo::Plain {
            1.0
        } else {
            1.0 / self.ratio.max(1e-9)
        }
    }
}

/// Ring allgather (§3.1.1 / Fig. 10). `bytes` is the FULL gathered size;
/// each rank contributes `bytes / n`.
pub fn sim_allgather(p: &SimParams, cm: &CostModel) -> SimReport {
    let n = p.n;
    let chunk = p.bytes / n as f64;
    let rate = cm.rate(p.kind);
    let (comp, decomp) = (rate.comp(p.multithread), rate.decomp(p.multithread));
    let mut t = vec![0.0f64; n];
    let mut b = SimBreakdown::default();

    match p.algo {
        Algo::Plain => {
            for _round in 0..n.saturating_sub(1) {
                lockstep_ring(&mut t, cm.link_s(chunk));
            }
            b.comm_s = (n.saturating_sub(1)) as f64 * cm.link_s(chunk);
        }
        Algo::Cprp2p => {
            // Per-hop codec + UNBALANCED compressed sends (§3.1.1).
            let cb = chunk * p.cfrac() * cm.imbalance;
            let per_round_pre = chunk / comp; // compress before send
            let per_round_post = chunk / decomp; // decompress after recv
            for _round in 0..n.saturating_sub(1) {
                for v in t.iter_mut() {
                    *v += per_round_pre;
                }
                lockstep_ring(&mut t, cm.link_s(cb));
                for v in t.iter_mut() {
                    *v += per_round_post;
                }
            }
            let r = (n.saturating_sub(1)) as f64;
            b.compress_s = r * per_round_pre;
            b.decompress_s = r * per_round_post;
            b.comm_s = r * cm.link_s(cb);
        }
        Algo::CColl | Algo::Zccl | Algo::Hier => {
            let cb = chunk * p.cfrac();
            // (1) one compression of the local chunk
            let tc = chunk / comp;
            for v in t.iter_mut() {
                *v += tc;
            }
            b.compress_s = tc;
            // (2) size exchange: n-1 tiny lockstep rounds
            for _ in 0..n.saturating_sub(1) {
                lockstep_ring(&mut t, cm.link_s(4.0));
            }
            b.other_s = (n.saturating_sub(1)) as f64 * cm.link_s(4.0);
            // (3) n-1 rounds of compressed chunks (balanced: equal cb)
            for _round in 0..n.saturating_sub(1) {
                lockstep_ring(&mut t, cm.link_s(cb));
            }
            b.comm_s = (n.saturating_sub(1)) as f64 * cm.link_s(cb);
            // (4) decompress all n chunks once
            let td = n as f64 * chunk / decomp;
            for v in t.iter_mut() {
                *v += td;
            }
            b.decompress_s = td;
        }
    }
    SimReport::from_ranks(t, b)
}

/// Ring reduce-scatter (§3.1.2 / Fig. 11). `bytes` is the full input size
/// (every rank holds `bytes`).
pub fn sim_reduce_scatter(p: &SimParams, cm: &CostModel) -> SimReport {
    let n = p.n;
    let chunk = p.bytes / n as f64;
    let rate = cm.rate(p.kind);
    let (comp, decomp) = (rate.comp(p.multithread), rate.decomp(p.multithread));
    let mut t = vec![0.0f64; n];
    let mut b = SimBreakdown::default();
    let rounds = n.saturating_sub(1) as f64;
    let treduce = chunk / cm.reduce_bps;

    match p.algo {
        Algo::Plain => {
            for _ in 0..n.saturating_sub(1) {
                lockstep_ring(&mut t, cm.link_s(chunk));
                for v in t.iter_mut() {
                    *v += treduce;
                }
            }
            b.comm_s = rounds * cm.link_s(chunk);
            b.compute_s = rounds * treduce;
        }
        Algo::Cprp2p | Algo::CColl => {
            // Blocking compress -> send -> recv -> decompress -> reduce.
            let cb = chunk * p.cfrac();
            let tc = chunk / comp;
            let td = chunk / decomp;
            for _ in 0..n.saturating_sub(1) {
                for v in t.iter_mut() {
                    *v += tc;
                }
                lockstep_ring(&mut t, cm.link_s(cb));
                for v in t.iter_mut() {
                    *v += td + treduce;
                }
            }
            b.compress_s = rounds * tc;
            b.decompress_s = rounds * td;
            b.comm_s = rounds * cm.link_s(cb);
            b.compute_s = rounds * treduce;
        }
        Algo::Zccl | Algo::Hier => {
            // PIPE overlap: the receive progresses while compressing; only
            // the part of the transfer longer than the compression is
            // exposed. Decompression likewise overlaps the send drain.
            let cb = chunk * p.cfrac();
            let tc = chunk / comp;
            let td = chunk / decomp;
            let tlink = cm.link_s(cb);
            let exposed = (tlink - tc - td).max(0.0) + cm.alpha_s;
            for _ in 0..n.saturating_sub(1) {
                for v in t.iter_mut() {
                    *v += tc;
                }
                lockstep_ring(&mut t, exposed);
                for v in t.iter_mut() {
                    *v += td + treduce;
                }
            }
            b.compress_s = rounds * tc;
            b.decompress_s = rounds * td;
            b.comm_s = rounds * exposed;
            b.compute_s = rounds * treduce;
        }
    }
    SimReport::from_ranks(t, b)
}

/// Ring allreduce = reduce-scatter + allgather (§3.5 / Figs. 9, 12, 13).
/// `bytes` is the input size per rank.
pub fn sim_allreduce(p: &SimParams, cm: &CostModel) -> SimReport {
    let rs = sim_reduce_scatter(p, cm);
    let ag = sim_allgather(p, cm);
    let per_rank: Vec<f64> =
        rs.per_rank_s.iter().zip(&ag.per_rank_s).map(|(a, c)| a + c).collect();
    let b = SimBreakdown {
        compress_s: rs.breakdown.compress_s + ag.breakdown.compress_s,
        decompress_s: rs.breakdown.decompress_s + ag.breakdown.decompress_s,
        comm_s: rs.breakdown.comm_s + ag.breakdown.comm_s,
        compute_s: rs.breakdown.compute_s + ag.breakdown.compute_s,
        other_s: rs.breakdown.other_s + ag.breakdown.other_s,
    };
    SimReport::from_ranks(per_rank, b)
}

/// Result of [`sim_allreduce_overlap`]: the bucketed nonblocking
/// allreduce overlapped with application compute, against the blocking
/// single-bucket baseline on the same inputs.
#[derive(Debug, Clone, Copy)]
pub struct OverlapSim {
    /// Critical-path seconds of the overlapped step
    /// (`compute + exposed`).
    pub total_s: f64,
    /// Collective time NOT hidden behind compute — what the application
    /// blocks on in `wait()`.
    pub exposed_comm_s: f64,
    /// Collective time hidden behind compute (driven by `test()` polls).
    pub hidden_comm_s: f64,
    /// The blocking baseline's step time (`compute + full collective`).
    pub blocking_total_s: f64,
    /// The blocking baseline's collective time — all of it exposed.
    pub blocking_comm_s: f64,
}

/// Bucketed nonblocking allreduce overlapped with `compute_s` seconds of
/// application work (the DDP backward pass), mirroring the real
/// `iallreduce` path in [`crate::apps::ddp`]: the gradient stream is cut
/// into `buckets` equal buckets, bucket `i` becomes ready (launches) at
/// `(i+1)/B · compute_s`, and in-flight collectives progress whenever the
/// link is free. Per-bucket collective cost is the blocking critical path
/// split `B` ways plus one extra α (smaller messages pay latency per
/// bucket — the overlap-granularity tax). The link serialises buckets:
/// a bucket starts when it is ready AND the link has drained its
/// predecessors. Whatever drains past the end of compute is exposed.
pub fn sim_allreduce_overlap(
    p: &SimParams,
    cm: &CostModel,
    compute_s: f64,
    buckets: usize,
) -> OverlapSim {
    let blocking = sim_allreduce(p, cm);
    let b = buckets.max(1);
    let per = blocking.makespan_s / b as f64 + cm.alpha_s;
    let comm_total = per * b as f64;
    let mut link_free = 0.0f64;
    for i in 0..b {
        let launch = (i as f64 + 1.0) / b as f64 * compute_s;
        let start = launch.max(link_free);
        link_free = start + per;
    }
    let exposed = (link_free - compute_s).max(0.0);
    OverlapSim {
        total_s: compute_s + exposed,
        exposed_comm_s: exposed,
        hidden_comm_s: comm_total - exposed,
        blocking_total_s: compute_s + blocking.makespan_s,
        blocking_comm_s: blocking.makespan_s,
    }
}

/// Hierarchical two-level allreduce ([`Algo::Hier`]) over
/// `p.n / ranks_per_node` nodes of `ranks_per_node` ranks: intra-node
/// raw star-reduce onto the leader (fast tier), the flat ZCCL allreduce
/// over the leaders only (slow tier, priced by [`sim_allreduce`]), then
/// an intra-node raw binomial bcast. With `ranks_per_node == 1` this is
/// exactly the flat model — the degenerate topology.
pub fn sim_allreduce_hier(p: &SimParams, ranks_per_node: usize, cm: &CostModel) -> SimReport {
    let rpn = ranks_per_node.clamp(1, p.n.max(1));
    let nodes = p.n.div_ceil(rpn);
    // Intra up: members stream raw partials into the leader's memory bus
    // back to back; the leader folds each one.
    let up_comm = (rpn - 1) as f64 * cm.intra_link_s(p.bytes);
    let up_fold = (rpn - 1) as f64 * p.bytes / cm.reduce_bps;
    // Inter: the unchanged flat schedule over the leader group.
    let inner = if nodes > 1 {
        sim_allreduce(&SimParams { n: nodes, algo: Algo::Zccl, ..*p }, cm)
    } else {
        SimReport::from_ranks(vec![0.0], SimBreakdown::default())
    };
    // Intra down: raw binomial bcast of the full result.
    let down_comm = tree_rounds(rpn) as f64 * cm.intra_link_s(p.bytes);
    let total = up_comm + up_fold + inner.makespan_s + down_comm;
    let mut b = inner.breakdown;
    b.comm_s += up_comm + down_comm;
    b.compute_s += up_fold;
    SimReport::from_ranks(vec![total; p.n], b)
}

/// Binomial broadcast (§3.1.1 Fig. 3 / Fig. 14). `bytes` is the broadcast
/// payload.
pub fn sim_bcast(p: &SimParams, cm: &CostModel) -> SimReport {
    let n = p.n;
    let rate = cm.rate(p.kind);
    let (comp, decomp) = (rate.comp(p.multithread), rate.decomp(p.multithread));
    let cb = p.bytes * p.cfrac();
    let tc = p.bytes / comp;
    let td = p.bytes / decomp;

    // Plain MPI_Bcast at these message sizes is NOT the binomial tree:
    // MPICH switches to scatter + ring-allgather for large messages,
    // costing ~2·(n-1)/n·bytes of link time. The compressed modes follow
    // the paper's binomial design (Fig. 3).
    if p.algo == Algo::Plain {
        let t = 2.0 * (n as f64 - 1.0) / n as f64 * p.bytes / cm.link_bps
            + tree_rounds(n) as f64 * cm.alpha_s;
        let b = SimBreakdown { comm_s: t, ..Default::default() };
        return SimReport::from_ranks(vec![t; n], b);
    }

    // Event-driven over the tree: ready[r] = when rank r has the payload
    // and may start forwarding.
    let mut ready = vec![f64::INFINITY; n];
    let root = 0usize;
    let mut b = SimBreakdown::default();
    ready[root] = match p.algo {
        Algo::Plain => 0.0,
        Algo::Cprp2p => 0.0, // compresses per send below
        Algo::CColl | Algo::Zccl | Algo::Hier => tc,
    };
    // Process ranks in BFS order of the binomial tree.
    let order = bfs_order(root, n);
    let mut done = vec![0.0f64; n];
    for &r in &order {
        let (_, sends) = binomial_bcast(r, root, n);
        let mut nic_free = ready[r];
        for s in &sends {
            // Serial sends occupy the sender's NIC back to back.
            let (payload, pre) = match p.algo {
                Algo::Plain => (p.bytes, 0.0),
                Algo::Cprp2p => (cb, tc), // re-compress before each send
                Algo::CColl | Algo::Zccl | Algo::Hier => (cb, 0.0),
            };
            nic_free += pre;
            let arrive = nic_free + cm.link_s(payload);
            nic_free += payload / cm.link_bps; // pipelined: NIC frees at drain
            let post = match p.algo {
                Algo::Plain => 0.0,
                Algo::Cprp2p => td, // decompress immediately on arrival
                Algo::CColl | Algo::Zccl | Algo::Hier => 0.0, // forwards frame verbatim
            };
            ready[s.peer] = arrive + post;
        }
        // Rank r's own completion: Z modes decompress after forwarding.
        done[r] = match p.algo {
            Algo::Plain | Algo::Cprp2p => nic_free.max(ready[r]),
            Algo::CColl | Algo::Zccl | Algo::Hier => nic_free.max(ready[r]) + td,
        };
    }
    // Critical-path breakdown (approximate: attribute along the deepest
    // leaf): depth rounds of links + per-mode codec work.
    let depth = tree_rounds(n) as f64;
    match p.algo {
        Algo::Plain => b.comm_s = depth * cm.link_s(p.bytes),
        Algo::Cprp2p => {
            b.comm_s = depth * cm.link_s(cb);
            b.compress_s = depth * tc;
            b.decompress_s = depth * td;
        }
        Algo::CColl | Algo::Zccl | Algo::Hier => {
            b.comm_s = depth * cm.link_s(cb);
            b.compress_s = tc;
            b.decompress_s = td;
        }
    }
    SimReport::from_ranks(done, b)
}

/// Binomial scatter (§4.5.2 / Fig. 15). `bytes` is the root's full buffer.
pub fn sim_scatter(p: &SimParams, cm: &CostModel) -> SimReport {
    let n = p.n;
    let rate = cm.rate(p.kind);
    let (comp, decomp) = (rate.comp(p.multithread), rate.decomp(p.multithread));
    let chunk = p.bytes / n as f64;
    let root = 0usize;
    let mut ready = vec![f64::INFINITY; n]; // when the rank holds its subtree block
    let mut b = SimBreakdown::default();
    // Root preprocessing: Z modes compress each chunk once (whole buffer).
    ready[root] = match p.algo {
        Algo::Plain => 0.0,
        Algo::Cprp2p => 0.0,
        Algo::CColl | Algo::Zccl | Algo::Hier => p.bytes / comp,
    };
    let order = bfs_order(root, n);
    let mut done = vec![0.0f64; n];
    let subtree_count = subtree_sizes(root, n);
    for &r in &order {
        let (_, sends) = binomial_bcast(r, root, n);
        let mut nic_free = ready[r];
        for s in &sends {
            let sub_bytes = subtree_count[s.peer] as f64 * chunk;
            let (payload, pre, post) = match p.algo {
                Algo::Plain => (sub_bytes, 0.0, 0.0),
                // CPRP2P compresses the whole forwarded block per hop and
                // the child decompresses it on arrival.
                Algo::Cprp2p => {
                    (sub_bytes * p.cfrac(), sub_bytes / comp, sub_bytes / decomp)
                }
                // Z modes forward per-rank frames untouched.
                Algo::CColl | Algo::Zccl | Algo::Hier => (sub_bytes * p.cfrac(), 0.0, 0.0),
            };
            nic_free += pre;
            let arrive = nic_free + cm.link_s(payload);
            nic_free += payload / cm.link_bps;
            ready[s.peer] = arrive + post;
        }
        // Own completion: Z modes decompress only the own chunk.
        done[r] = match p.algo {
            Algo::Plain | Algo::Cprp2p => nic_free.max(ready[r]),
            Algo::CColl | Algo::Zccl | Algo::Hier => nic_free.max(ready[r]) + chunk / decomp,
        };
    }
    let depth = tree_rounds(n) as f64;
    match p.algo {
        Algo::Plain => b.comm_s = depth * cm.link_s(p.bytes / 2.0),
        Algo::Cprp2p => {
            b.comm_s = depth * cm.link_s(p.bytes / 2.0 * p.cfrac());
            b.compress_s = p.bytes / comp; // ~half the data per level, x levels
            b.decompress_s = p.bytes / decomp;
        }
        Algo::CColl | Algo::Zccl | Algo::Hier => {
            b.comm_s = depth * cm.link_s(p.bytes / 2.0 * p.cfrac());
            b.compress_s = p.bytes / comp;
            b.decompress_s = chunk / decomp;
        }
    }
    SimReport::from_ranks(done, b)
}

/// One lockstep ring round: every rank must wait for its predecessor's
/// readiness before its receive completes.
fn lockstep_ring(t: &mut [f64], step: f64) {
    let n = t.len();
    let prev: Vec<f64> = t.to_vec();
    for r in 0..n {
        let src = (r + n - 1) % n;
        t[r] = prev[r].max(prev[src]) + step;
    }
}

fn bfs_order(root: usize, n: usize) -> Vec<usize> {
    let mut order = vec![root];
    let mut i = 0;
    while i < order.len() {
        let (_, sends) = binomial_bcast(order[i], root, n);
        for s in sends {
            order.push(s.peer);
        }
        i += 1;
    }
    order
}

fn subtree_sizes(root: usize, n: usize) -> Vec<usize> {
    let mut sizes = vec![0usize; n];
    // Process ranks deepest-first (reverse BFS) accumulating children.
    let order = bfs_order(root, n);
    for &r in order.iter().rev() {
        let (_, sends) = binomial_bcast(r, root, n);
        sizes[r] = 1 + sends.iter().map(|s| sizes[s.peer]).sum::<usize>();
    }
    sizes
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(algo: Algo, n: usize, mb: f64, ratio: f64, mt: bool) -> SimParams {
        SimParams {
            n,
            bytes: mb * 1e6,
            algo,
            kind: CompressorKind::FzLight,
            multithread: mt,
            ratio,
        }
    }

    #[test]
    fn subtree_sizes_sum_to_n() {
        for n in [1usize, 2, 5, 8, 13, 128] {
            let s = subtree_sizes(0, n);
            assert_eq!(s[0], n);
        }
    }

    #[test]
    fn zccl_allgather_beats_cprp2p() {
        // Fig. 10's shape: ZCCL > CPRP2P by ~2-4x at 64 ranks.
        let cm = CostModel::paper_broadwell();
        let z = sim_allgather(&p(Algo::Zccl, 64, 300.0, 10.0, false), &cm);
        let c = sim_allgather(&p(Algo::Cprp2p, 64, 300.0, 10.0, false), &cm);
        let speedup = c.makespan_s / z.makespan_s;
        assert!(speedup > 1.5 && speedup < 30.0, "speedup {speedup}");
    }

    #[test]
    fn zccl_allreduce_beats_plain_mpi() {
        // Fig. 12: ZCCL ST ~1.9x, MT ~3.5x over MPI at 64 nodes / 600 MB.
        let cm = CostModel::paper_broadwell();
        let mpi = sim_allreduce(&p(Algo::Plain, 64, 600.0, 10.0, false), &cm);
        let st = sim_allreduce(&p(Algo::Zccl, 64, 600.0, 10.0, false), &cm);
        let mt = sim_allreduce(&p(Algo::Zccl, 64, 600.0, 10.0, true), &cm);
        let s_st = mpi.makespan_s / st.makespan_s;
        let s_mt = mpi.makespan_s / mt.makespan_s;
        assert!(s_st > 1.0, "ST speedup {s_st} should exceed 1");
        assert!(s_mt > s_st, "MT {s_mt} should beat ST {s_st}");
        assert!(s_mt < 12.0, "MT speedup {s_mt} implausible");
    }

    #[test]
    fn zccl_bcast_speedup_grows_with_ratio() {
        let cm = CostModel::paper_broadwell();
        let plain = sim_bcast(&p(Algo::Plain, 64, 300.0, 1.0, true), &cm);
        let lo = sim_bcast(&p(Algo::Zccl, 64, 300.0, 5.0, true), &cm);
        let hi = sim_bcast(&p(Algo::Zccl, 64, 300.0, 30.0, true), &cm);
        assert!(plain.makespan_s / lo.makespan_s > 1.0);
        assert!(
            plain.makespan_s / hi.makespan_s > plain.makespan_s / lo.makespan_s,
            "higher ratio must help more"
        );
    }

    #[test]
    fn cprp2p_bcast_pays_per_hop_codec() {
        let cm = CostModel::paper_broadwell();
        let z = sim_bcast(&p(Algo::Zccl, 64, 300.0, 10.0, false), &cm);
        let c = sim_bcast(&p(Algo::Cprp2p, 64, 300.0, 10.0, false), &cm);
        assert!(c.makespan_s > z.makespan_s);
        assert!(c.breakdown.compress_s > 2.0 * z.breakdown.compress_s);
    }

    #[test]
    fn reduce_scatter_overlap_reduces_exposed_comm() {
        let cm = CostModel::paper_broadwell();
        let blocking = sim_reduce_scatter(&p(Algo::CColl, 64, 300.0, 10.0, false), &cm);
        let piped = sim_reduce_scatter(&p(Algo::Zccl, 64, 300.0, 10.0, false), &cm);
        assert!(piped.breakdown.comm_s < blocking.breakdown.comm_s);
        assert!(piped.makespan_s <= blocking.makespan_s);
    }

    #[test]
    fn scaling_shape_monotone() {
        // Fig. 13: fixed data size, growing node count — ZCCL stays ahead
        // of plain MPI at every n.
        let cm = CostModel::paper_broadwell();
        for n in [2usize, 4, 8, 16, 32, 64, 128] {
            let mpi = sim_allreduce(&p(Algo::Plain, n, 678.0, 28.0, false), &cm);
            let z = sim_allreduce(&p(Algo::Zccl, n, 678.0, 28.0, true), &cm);
            assert!(
                z.makespan_s < mpi.makespan_s,
                "n={n}: zccl {} vs mpi {}",
                z.makespan_s,
                mpi.makespan_s
            );
        }
    }

    #[test]
    fn overlap_exposed_comm_shrinks_with_compute() {
        // More backward-pass compute to hide behind -> less exposed
        // communication, down to the last bucket's cost (which can never
        // be hidden: it only becomes ready when compute ends).
        let cm = CostModel::paper_broadwell();
        let params = p(Algo::Zccl, 16, 100.0, 10.0, false);
        let blocking = sim_allreduce(&params, &cm).makespan_s;
        let mut prev = f64::INFINITY;
        for k in 0..8 {
            let compute_s = blocking * k as f64 / 2.0;
            let o = sim_allreduce_overlap(&params, &cm, compute_s, 8);
            assert!(
                o.exposed_comm_s <= prev + 1e-12,
                "exposed must be non-increasing in compute ({} after {prev})",
                o.exposed_comm_s
            );
            assert!(o.total_s <= o.blocking_total_s + 1e-12, "overlap can never lose");
            prev = o.exposed_comm_s;
        }
        // With zero compute nothing can hide; with ample compute only the
        // final bucket is exposed.
        let none = sim_allreduce_overlap(&params, &cm, 0.0, 8);
        assert!(none.hidden_comm_s < 1e-12);
        let ample = sim_allreduce_overlap(&params, &cm, blocking * 10.0, 8);
        assert!(ample.exposed_comm_s < blocking / 4.0, "most comm should hide");
    }

    #[test]
    fn overlap_accounting_conserves_comm() {
        // hidden + exposed must equal the nonblocking schedule's total
        // collective work (blocking critical path + per-bucket alpha tax).
        let cm = CostModel::paper_broadwell();
        let params = p(Algo::Zccl, 32, 300.0, 10.0, false);
        let blocking = sim_allreduce(&params, &cm).makespan_s;
        for buckets in [1usize, 3, 8] {
            let nb_total = blocking + buckets as f64 * cm.alpha_s;
            for compute_s in [0.0, blocking * 0.5, blocking * 3.0] {
                let o = sim_allreduce_overlap(&params, &cm, compute_s, buckets);
                let sum = o.hidden_comm_s + o.exposed_comm_s;
                assert!(
                    (sum - nb_total).abs() < 1e-9,
                    "buckets={buckets}: {sum} vs {nb_total}"
                );
                assert!(o.hidden_comm_s >= 0.0 && o.exposed_comm_s >= 0.0);
                assert!((o.blocking_comm_s - blocking).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn single_rank_degenerate() {
        let cm = CostModel::paper_broadwell();
        let r = sim_allreduce(&p(Algo::Zccl, 1, 10.0, 10.0, false), &cm);
        assert!(r.makespan_s < 0.2);
    }

    #[test]
    fn hier_with_one_rank_per_node_is_flat() {
        let cm = CostModel::paper_broadwell();
        let flat = sim_allreduce(&p(Algo::Zccl, 32, 300.0, 10.0, false), &cm);
        let hier = sim_allreduce_hier(&p(Algo::Hier, 32, 300.0, 10.0, false), 1, &cm);
        assert!(
            (hier.makespan_s - flat.makespan_s).abs() < 1e-12,
            "rpn=1 must collapse to the flat model"
        );
    }

    #[test]
    fn hier_beats_flat_on_dense_nodes() {
        // 64 ranks as 8 nodes x 8: only 8 leaders ring compressed frames
        // over the slow tier instead of 64 ranks — the intra raw hops are
        // cheap next to the saved inter-node rounds.
        let cm = CostModel::paper_broadwell();
        let flat = sim_allreduce(&p(Algo::Zccl, 64, 300.0, 10.0, false), &cm);
        let hier = sim_allreduce_hier(&p(Algo::Hier, 64, 300.0, 10.0, false), 8, &cm);
        assert!(
            hier.makespan_s < flat.makespan_s,
            "hier {} vs flat {}",
            hier.makespan_s,
            flat.makespan_s
        );
    }

    #[test]
    fn hier_flat_sim_arms_accept_hier_algo() {
        // The flat models price Algo::Hier like Zccl (used when a flat
        // stage runs under a hierarchical mode).
        let cm = CostModel::paper_broadwell();
        let z = sim_allgather(&p(Algo::Zccl, 16, 100.0, 10.0, false), &cm);
        let h = sim_allgather(&p(Algo::Hier, 16, 100.0, 10.0, false), &cm);
        assert!((z.makespan_s - h.makespan_s).abs() < 1e-12);
    }
}
