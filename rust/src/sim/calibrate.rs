//! Calibration: feed the simulator real numbers measured on this host.
//!
//! Two knobs connect the simulator to reality:
//!
//! 1. **Compression ratios** are never modeled — [`sample_ratio`] runs the
//!    actual codec on a sampled synthetic field and returns the measured
//!    ratio, which the simulations scale by.
//! 2. **Local throughputs** — [`local_model`] measures this host's
//!    compressor bandwidths so simulated small-rank runs can be
//!    cross-checked against real `memchan` executions
//!    (`rust/tests/sim_crosscheck.rs`).

use super::collectives::{sim_allreduce, sim_allreduce_hier, SimParams};
use super::{CodecRate, CostModel};
use crate::collectives::Algo;
use crate::compress::{self, CompressorKind, ErrorBound};
use crate::data::fields::{Field, FieldKind};
use crate::util::bench::measure_for;

/// Measure the compression ratio of `kind` on a sampled field at `eb`.
/// The sample is `sample_values` long (1 MiB of f32 by default covers the
/// generators' longest correlation lengths).
pub fn sample_ratio(
    kind: CompressorKind,
    field: FieldKind,
    eb: ErrorBound,
    sample_values: usize,
    seed: u64,
) -> f64 {
    let f = Field::generate(field, sample_values.max(1024), seed);
    match compress::build(kind).compress(&f.values, eb) {
        Ok(c) => c.stats.ratio().max(1.0),
        Err(_) => 1.0,
    }
}

/// Measure this host's single-thread codec bandwidths (bytes/s). The
/// multi-thread columns reuse the single-thread number scaled by the
/// paper's Broadwell thread-scaling factor (this container has one core,
/// DESIGN.md §2).
pub fn local_model(budget_s: f64) -> CostModel {
    let paper = CostModel::paper_broadwell();
    let mut cm = CostModel {
        // Keep the paper's network; only codec rates are local.
        ..paper.clone()
    };
    let field = Field::generate(FieldKind::Rtm, 1 << 20, 7);
    let eb = ErrorBound::Rel(1e-4);
    let bytes = field.values.len() * 4;
    for kind in [CompressorKind::FzLight, CompressorKind::Szx] {
        let codec = compress::build(kind);
        let frame = codec.compress(&field.values, eb).unwrap();
        let comp = measure_for(budget_s, || codec.compress(&field.values, eb).unwrap());
        let decomp = measure_for(budget_s, || codec.decompress(&frame.bytes).unwrap());
        let paper_rate = paper.rate(kind);
        let mt_scale_c = paper_rate.comp_mt / paper_rate.comp_st;
        let mt_scale_d = paper_rate.decomp_mt / paper_rate.decomp_st;
        let rate = CodecRate {
            comp_st: comp.gbps(bytes) * 1e9,
            decomp_st: decomp.gbps(bytes) * 1e9,
            comp_mt: comp.gbps(bytes) * 1e9 * mt_scale_c,
            decomp_mt: decomp.gbps(bytes) * 1e9 * mt_scale_d,
        };
        match kind {
            CompressorKind::FzLight => cm.fzlight = rate,
            CompressorKind::Szx => cm.szx = rate,
            _ => unreachable!(),
        }
    }
    cm
}

/// Pick the faster allreduce framework for this shape under the per-tier
/// cost model: flat ZCCL (every rank on the slow tier) vs the two-level
/// hierarchical schedule (`p.n / ranks_per_node` leaders on the slow
/// tier, raw hops inside each node). Ties go to flat — the simpler
/// schedule with no leader hot spot.
pub fn pick_allreduce_algo(p: &SimParams, ranks_per_node: usize, cm: &CostModel) -> Algo {
    let flat = sim_allreduce(&SimParams { algo: Algo::Zccl, ..*p }, cm);
    let hier = sim_allreduce_hier(&SimParams { algo: Algo::Hier, ..*p }, ranks_per_node, cm);
    if hier.makespan_s < flat.makespan_s {
        Algo::Hier
    } else {
        Algo::Zccl
    }
}

/// Smallest segment the picker will return (one page-cluster: below this
/// the per-segment bookkeeping dominates any overlap win).
pub const MIN_SEGMENT_BYTES: usize = 1 << 12;
/// Largest segment the picker will return (past this a segment is
/// effectively monolithic for the transfer sizes this repo benches).
pub const MAX_SEGMENT_BYTES: usize = 1 << 22;

/// Pick the §3.5.1 fixed segment size for one `total_bytes` transfer on
/// the chosen tier under the postal model: segmenting a store-and-forward
/// chain costs `(total/s) · (α + s/β)` for the stream plus `O(depth)`
/// fill, which is minimised at `s* = sqrt(total · α · β)` — bigger
/// transfers and lossier (higher `α·β`) links both want bigger segments.
/// The result is clamped to `[MIN_SEGMENT_BYTES, MAX_SEGMENT_BYTES]` and,
/// from below, so the transfer fits the per-round tag window
/// (`total / s ≤ SEG_TAG_SPAN`). Feed the result to
/// [`crate::collectives::Mode::pipeline_bytes`].
pub fn pick_segment_bytes(total_bytes: f64, cm: &CostModel, intra: bool) -> usize {
    let (alpha, bps) =
        if intra { (cm.intra_alpha_s, cm.intra_bps) } else { (cm.alpha_s, cm.link_bps) };
    let total = total_bytes.max(0.0);
    let star = (total * alpha * bps).sqrt();
    let floor_for_span = total / crate::collectives::SEG_TAG_SPAN as f64;
    let s = star.max(floor_for_span).ceil() as usize;
    s.clamp(MIN_SEGMENT_BYTES, MAX_SEGMENT_BYTES)
}

/// Whether the intra-node tier should carry compressed frames instead of
/// raw `f32` hops for `bytes`-sized payloads at measured `ratio`:
/// compress + ship `bytes/ratio` + decompress must beat shipping `bytes`
/// raw on the fast tier. Per-message latency is identical on both sides
/// (same hop count), so only the bandwidth terms compete — on the paper's
/// testbed the single-thread codecs lose to the 8 GB/s fast tier and only
/// the multi-thread rates at a healthy ratio flip the decision. Feed the
/// result to [`crate::collectives::CollCtx::set_intra_mode`].
pub fn pick_intra_mode(
    bytes: f64,
    kind: CompressorKind,
    multithread: bool,
    ratio: f64,
    cm: &CostModel,
) -> bool {
    let rate = cm.rate(kind);
    let ratio = ratio.max(1.0);
    let raw_s = bytes / cm.intra_bps;
    let compressed_s = bytes / rate.comp(multithread)
        + bytes / ratio / cm.intra_bps
        + bytes / rate.decomp(multithread);
    compressed_s < raw_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_sampling_orders_fields() {
        let eb = ErrorBound::Rel(1e-4);
        let rtm = sample_ratio(CompressorKind::FzLight, FieldKind::Rtm, eb, 1 << 16, 3);
        let nyx = sample_ratio(CompressorKind::FzLight, FieldKind::Nyx, eb, 1 << 16, 3);
        assert!(rtm > nyx, "rtm {rtm} vs nyx {nyx}");
        assert!(rtm > 1.0 && nyx > 1.0);
    }

    #[test]
    fn local_model_produces_positive_rates() {
        let cm = local_model(0.02);
        assert!(cm.fzlight.comp_st > 1e6, "fzlight {:.3e}", cm.fzlight.comp_st);
        assert!(cm.szx.comp_st > 1e6);
        assert!(cm.fzlight.comp_mt > cm.fzlight.comp_st);
    }

    #[test]
    fn picker_prefers_hier_on_dense_nodes_and_flat_on_sparse() {
        let cm = CostModel::paper_broadwell();
        let p = SimParams {
            n: 64,
            bytes: 300e6,
            algo: Algo::Zccl,
            kind: CompressorKind::FzLight,
            multithread: false,
            ratio: 10.0,
        };
        assert_eq!(pick_allreduce_algo(&p, 8, &cm), Algo::Hier);
        // One rank per node: the hierarchy adds nothing — ties go flat.
        assert_eq!(pick_allreduce_algo(&p, 1, &cm), Algo::Zccl);
    }

    #[test]
    fn segment_picker_grows_with_transfer_and_respects_clamps() {
        let cm = CostModel::paper_broadwell();
        let small = pick_segment_bytes(1e6, &cm, false);
        let big = pick_segment_bytes(100e6, &cm, false);
        assert!(big > small, "100 MB picks {big}, 1 MB picks {small}");
        for &b in &[0.0, 1.0, 1e3, 1e6, 1e9, 1e12] {
            for intra in [false, true] {
                let s = pick_segment_bytes(b, &cm, intra);
                assert!((MIN_SEGMENT_BYTES..=MAX_SEGMENT_BYTES).contains(&s), "{b} -> {s}");
                // The per-round tag window always fits the segment count.
                assert!(
                    (b / s as f64).ceil() as u64 <= crate::collectives::SEG_TAG_SPAN,
                    "{b} bytes / {s} overflows the tag window"
                );
            }
        }
        // The slow tier's higher α·β product wants bigger segments.
        assert!(pick_segment_bytes(100e6, &cm, false) >= pick_segment_bytes(100e6, &cm, true));
    }

    #[test]
    fn intra_mode_picker_needs_multithread_rates_and_real_ratio() {
        let cm = CostModel::paper_broadwell();
        let b = 100e6;
        // Single-thread fZ-light (2.61 GB/s) cannot beat the 8 GB/s tier.
        assert!(!pick_intra_mode(b, CompressorKind::FzLight, false, 10.0, &cm));
        // Multi-thread at a healthy ratio wins...
        assert!(pick_intra_mode(b, CompressorKind::FzLight, true, 10.0, &cm));
        // ...but not at ratio ~1 (all codec cost, no byte savings).
        assert!(!pick_intra_mode(b, CompressorKind::FzLight, true, 1.0, &cm));
    }
}
