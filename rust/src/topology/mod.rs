//! Communication schedules and the two-level topology layer.
//!
//! ## Flat primitives
//!
//! The paper integrates compression into two flat schedule families — the
//! ring (allgather / reduce-scatter, §3.1.1–3.1.2) and the MPICH binomial
//! tree (bcast / scatter, §4.5). [`ring`], [`ring_send_chunk`] /
//! [`ring_recv_chunk`], [`binomial_bcast`] and [`binomial_subtree`] are
//! those primitives, expressed over a dense rank space `0..n`.
//!
//! ## The two-level schedule API
//!
//! Real deployments are hierarchical: cheap intra-node links and
//! expensive inter-node links (gZCCL, arXiv:2308.05199). [`Topology`]
//! captures that shape — a rank→node map, one elected leader per node,
//! and a [`LinkClass`] per rank pair — and the *group-mapped* schedule
//! generators ([`ring_in_group`], [`binomial_bcast_in_group`],
//! [`binomial_subtree_into`]) re-express the flat primitives over an
//! arbitrary rank subset, so a hierarchical collective composes them per
//! tier:
//!
//! - the **inter-node tier** runs a flat schedule over
//!   [`Topology::leaders`] (a ring for allreduce/allgather, a binomial
//!   tree for bcast/scatter), carrying *compressed* frames that are
//!   forwarded verbatim — compress-once extended across tiers;
//! - the **intra-node tier** runs a star or binomial schedule over
//!   [`Topology::members`], carrying raw `f32` windows over the fast
//!   links by default (only leaders compress/decompress).
//!
//! ### Intra-tier mode contract
//!
//! The intra tier's codec is independently switchable
//! ([`crate::collectives::CollCtx::set_intra_mode`]): any non-`Hier`
//! mode is accepted, and a compressing intra mode changes only *how a
//! hop is encoded* — each intra payload is compressed exactly once per
//! hop by its producer and decoded exactly once by its consumer, never
//! re-encoded at the leader, so the message graph (peers, tags, counts)
//! is byte-for-byte the one the raw tier produces and the error bound
//! composes as one extra `D∘C` per intra hop. The
//! [`crate::sim`] cost model prices the two tiers separately so
//! `calibrate` can pick flat vs hierarchical per message size,
//! [`crate::sim::calibrate::pick_intra_mode`] decides raw vs compressed
//! intra hops, and [`crate::sim::calibrate::pick_segment_bytes`] sizes
//! the inter-leader pipeline segment.

use crate::{Error, Result};

/// Ring neighbours of `rank` in a communicator of `n`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RingNeighbors {
    /// Rank we send to (`rank + 1`).
    pub next: usize,
    /// Rank we receive from (`rank - 1`).
    pub prev: usize,
}

/// Ring neighbours.
pub fn ring(rank: usize, n: usize) -> RingNeighbors {
    debug_assert!(rank < n && n > 0);
    RingNeighbors { next: (rank + 1) % n, prev: (rank + n - 1) % n }
}

/// Ring neighbours within an arbitrary rank `group`: the member at
/// position `idx` talks to the members at the adjacent positions, with
/// peers reported as **global** ranks. This is the inter-tier face of the
/// flat [`ring`]: a leader ring is `ring_in_group(topo.leaders(), lidx)`.
pub fn ring_in_group(group: &[usize], idx: usize) -> RingNeighbors {
    let nb = ring(idx, group.len());
    RingNeighbors { next: group[nb.next], prev: group[nb.prev] }
}

/// In the standard ring schedule, the chunk that `rank` *sends* in round
/// `round` (0-based) of an allgather / the chunk it contributes in
/// reduce-scatter.
pub fn ring_send_chunk(rank: usize, round: usize, n: usize) -> usize {
    (rank + n - round % n) % n
}

/// The chunk `rank` *receives* in round `round` of the ring schedule.
pub fn ring_recv_chunk(rank: usize, round: usize, n: usize) -> usize {
    (rank + n - round % n - 1) % n
}

/// One step of a binomial-tree schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TreeStep {
    /// Round index (0-based; round `k` spans distance `2^k` in the
    /// standard MPICH formulation counting down from the top bit).
    pub round: usize,
    /// Peer rank for this step.
    pub peer: usize,
}

/// Binomial-tree broadcast schedule for `rank` rooted at `root`.
///
/// Returns `(recv_from, sends)`: the (at most one) parent this rank
/// receives from, then the ordered list of children it forwards to.
/// Matches MPICH's `MPIR_Bcast_intra_binomial`: relative rank
/// `vrank = (rank - root) mod n`; in the receiving phase the mask grows
/// from 1, in the sending phase it shrinks back down.
pub fn binomial_bcast(rank: usize, root: usize, n: usize) -> (Option<TreeStep>, Vec<TreeStep>) {
    debug_assert!(rank < n && root < n && n > 0);
    let vrank = (rank + n - root) % n;
    let logtop = tree_rounds(n);
    // Receive phase: the lowest set bit of vrank names the parent; the
    // round is the step at which the parent reaches this subtree (the root
    // sends its largest-mask child first, at round 0).
    let mut recv = None;
    let mut mask = 1usize;
    while mask < n {
        if vrank & mask != 0 {
            let vpeer = vrank - mask;
            let round = logtop - 1 - mask.trailing_zeros() as usize;
            recv = Some(TreeStep { round, peer: (vpeer + root) % n });
            break;
        }
        mask <<= 1;
    }
    if vrank == 0 {
        mask = 1usize << logtop;
    }
    // Send phase (MPICH mask-halving): children get masks below our own
    // lowest set bit, largest (earliest round) first.
    let mut sends = Vec::new();
    let mut m = mask >> 1;
    while m > 0 {
        let vchild = vrank + m;
        if vchild < n {
            sends.push(TreeStep {
                round: logtop - 1 - m.trailing_zeros() as usize,
                peer: (vchild + root) % n,
            });
        }
        m >>= 1;
    }
    (recv, sends)
}

/// [`binomial_bcast`] over an arbitrary rank `group`: positions within
/// the group form the tree, peers are reported as **global** ranks. A
/// hierarchical bcast runs
/// `binomial_bcast_in_group(topo.leaders(), lidx, root_node)` for its
/// inter tier and `binomial_bcast_in_group(topo.members(node), k, 0)`
/// for its intra tier — the same primitive composed per tier.
pub fn binomial_bcast_in_group(
    group: &[usize],
    idx: usize,
    root_idx: usize,
) -> (Option<TreeStep>, Vec<TreeStep>) {
    let (recv, sends) = binomial_bcast(idx, root_idx, group.len());
    (
        recv.map(|s| TreeStep { round: s.round, peer: group[s.peer] }),
        sends
            .into_iter()
            .map(|s| TreeStep { round: s.round, peer: group[s.peer] })
            .collect(),
    )
}

/// Number of rounds a binomial tree takes over `n` ranks (`ceil(log2 n)`).
pub fn tree_rounds(n: usize) -> usize {
    if n <= 1 {
        0
    } else {
        usize::BITS as usize - (n - 1).leading_zeros() as usize
    }
}

/// The set of descendant ranks of `rank` in the binomial scatter tree
/// rooted at `root` (the ranks whose data must flow through `rank`),
/// including `rank` itself. Used by Z-Scatter (flat and hierarchical) to
/// forward only the needed compressed chunks.
pub fn binomial_subtree(rank: usize, root: usize, n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    binomial_subtree_into(rank, root, n, &mut out);
    out
}

/// [`binomial_subtree`] into a caller-owned accumulator (appended, not
/// cleared): iterative worklist walk deriving each member's children
/// masks directly, so there is no per-call recursion and no transient
/// `Vec` per visited rank — the old recursive form allocated one child
/// list per descendant. `out[start]` is always `rank` itself; descendants
/// follow in breadth-first order.
pub fn binomial_subtree_into(rank: usize, root: usize, n: usize, out: &mut Vec<usize>) {
    debug_assert!(rank < n && root < n && n > 0);
    let start = out.len();
    out.push(rank);
    let mut i = start;
    while i < out.len() {
        let r = out[i];
        let vrank = (r + n - root) % n;
        // Children carry masks strictly below our own lowest set bit
        // (below the tree top for the root) — the send phase of
        // `binomial_bcast` without materializing the steps.
        let top = if vrank == 0 {
            1usize << tree_rounds(n)
        } else {
            vrank & vrank.wrapping_neg()
        };
        let mut m = top >> 1;
        while m > 0 {
            let vchild = vrank + m;
            if vchild < n {
                out.push((vchild + root) % n);
            }
            m >>= 1;
        }
        i += 1;
    }
}

/// Which tier a rank pair's link belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkClass {
    /// Same node: the fast tier (shared memory / NVLink class).
    Intra,
    /// Different nodes: the slow tier (the network the compressed frames
    /// are meant for).
    Inter,
}

/// A two-level topology: which node each rank lives on, plus the elected
/// intra-node leader (the lowest rank of each node). Nodes are dense
/// (`0..nodes()`), every node is non-empty, and `leaders()[j]` is the
/// leader of node `j` — so a node index doubles as the leader's position
/// in the leader group, which is what the inter-tier schedules run over.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    /// Node id per rank.
    node_of: Vec<usize>,
    /// Ranks per node, ascending.
    members: Vec<Vec<usize>>,
    /// Leader rank per node (lowest member).
    leaders: Vec<usize>,
}

impl Topology {
    /// Build from an explicit rank→node map. Node ids must be dense
    /// (`0..=max` all present) and every node non-empty.
    pub fn from_map(node_of: Vec<usize>) -> Result<Topology> {
        if node_of.is_empty() {
            return Err(Error::invalid("topology needs at least one rank"));
        }
        let nodes = node_of.iter().max().unwrap() + 1;
        // Dense non-empty nodes imply nodes <= ranks; reject oversized ids
        // BEFORE sizing the member table, so a bogus map errors instead of
        // allocating max_id vectors.
        if nodes > node_of.len() {
            return Err(Error::invalid(format!(
                "topology node id {} out of range for {} ranks (node ids must be dense)",
                nodes - 1,
                node_of.len()
            )));
        }
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); nodes];
        for (rank, &node) in node_of.iter().enumerate() {
            members[node].push(rank);
        }
        for (node, m) in members.iter().enumerate() {
            if m.is_empty() {
                return Err(Error::invalid(format!(
                    "topology node {node} has no ranks (node ids must be dense)"
                )));
            }
        }
        let leaders = members.iter().map(|m| m[0]).collect();
        Ok(Topology { node_of, members, leaders })
    }

    /// Every rank its own node (`n` nodes × 1 rank): the degenerate map
    /// under which every hierarchical schedule collapses to its flat
    /// counterpart. The default when a hierarchical mode runs without an
    /// explicit topology.
    pub fn flat(n: usize) -> Topology {
        Topology::from_map((0..n).collect()).expect("flat map is always valid")
    }

    /// `nodes` nodes × `per_node` consecutive ranks (rank `r` on node
    /// `r / per_node`) — the shape cluster launchers hand out.
    pub fn blocked(nodes: usize, per_node: usize) -> Topology {
        assert!(nodes > 0 && per_node > 0, "blocked topology needs nodes and ranks");
        Topology::from_map((0..nodes * per_node).map(|r| r / per_node).collect())
            .expect("blocked map is always valid")
    }

    /// Consecutive nodes of the given (possibly uneven) sizes, e.g.
    /// `grouped(&[3, 1, 2])` puts ranks 0–2 on node 0, rank 3 on node 1,
    /// ranks 4–5 on node 2.
    pub fn grouped(sizes: &[usize]) -> Result<Topology> {
        let mut map = Vec::new();
        for (node, &s) in sizes.iter().enumerate() {
            if s == 0 {
                return Err(Error::invalid(format!("topology node {node} has size 0")));
            }
            map.extend(std::iter::repeat(node).take(s));
        }
        Topology::from_map(map)
    }

    /// Total ranks.
    pub fn ranks(&self) -> usize {
        self.node_of.len()
    }

    /// Node count.
    pub fn nodes(&self) -> usize {
        self.members.len()
    }

    /// The node `rank` lives on.
    pub fn node_of(&self, rank: usize) -> usize {
        self.node_of[rank]
    }

    /// The ranks of `node`, ascending (the leader first).
    pub fn members(&self, node: usize) -> &[usize] {
        &self.members[node]
    }

    /// Every node's leader, indexed by node — the inter-tier group.
    pub fn leaders(&self) -> &[usize] {
        &self.leaders
    }

    /// The leader of `rank`'s node.
    pub fn leader_of(&self, rank: usize) -> usize {
        self.leaders[self.node_of[rank]]
    }

    /// Whether `rank` is its node's leader.
    pub fn is_leader(&self, rank: usize) -> bool {
        self.leader_of(rank) == rank
    }

    /// `rank`'s position within its node's member list.
    pub fn local_index(&self, rank: usize) -> usize {
        self.members[self.node_of[rank]]
            .iter()
            .position(|&r| r == rank)
            .expect("rank is in its own node")
    }

    /// The tier the `a`↔`b` link belongs to (self-links are intra).
    pub fn link_class(&self, a: usize, b: usize) -> LinkClass {
        if self.node_of[a] == self.node_of[b] {
            LinkClass::Intra
        } else {
            LinkClass::Inter
        }
    }

    /// Whether any node holds more than one rank (i.e. the two tiers are
    /// actually distinct).
    pub fn is_hierarchical(&self) -> bool {
        self.members.iter().any(|m| m.len() > 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_chunks_cover_everything() {
        // Over n-1 rounds of the allgather schedule, each rank receives all
        // chunks except its own.
        let n = 8;
        for rank in 0..n {
            let mut got = vec![false; n];
            got[rank] = true;
            for round in 0..n - 1 {
                let c = ring_recv_chunk(rank, round, n);
                assert!(!got[c], "duplicate chunk {c} at rank {rank} round {round}");
                got[c] = true;
            }
            assert!(got.iter().all(|&g| g));
        }
    }

    #[test]
    fn ring_send_matches_prev_recv() {
        // What rank r sends in round t is what rank r+1 receives in round t.
        let n = 7;
        for rank in 0..n {
            for round in 0..n - 1 {
                let sent = ring_send_chunk(rank, round, n);
                let recv = ring_recv_chunk((rank + 1) % n, round, n);
                assert_eq!(sent, recv);
            }
        }
    }

    #[test]
    fn binomial_reaches_everyone_once() {
        for n in [1usize, 2, 3, 4, 5, 8, 13, 16, 64, 100] {
            for root in [0, n / 2, n - 1] {
                let mut received = vec![0usize; n];
                received[root] += 1; // root starts with the data
                for rank in 0..n {
                    let (recv, _) = binomial_bcast(rank, root, n);
                    if let Some(r) = recv {
                        assert_ne!(rank, root, "root must not receive");
                        let _ = r;
                        received[rank] += 1;
                    }
                }
                for (rank, &c) in received.iter().enumerate() {
                    assert_eq!(c, 1, "rank {rank} n {n} root {root}");
                }
            }
        }
    }

    #[test]
    fn binomial_send_recv_pair_up() {
        // Every child's recv step must appear in its parent's send list
        // with the same round.
        for n in [2usize, 5, 8, 16, 33] {
            let root = 1 % n;
            for rank in 0..n {
                let (recv, _) = binomial_bcast(rank, root, n);
                if let Some(step) = recv {
                    let (_, parent_sends) = binomial_bcast(step.peer, root, n);
                    assert!(
                        parent_sends.iter().any(|s| s.peer == rank && s.round == step.round),
                        "n={n} rank={rank} parent={} round={}",
                        step.peer,
                        step.round
                    );
                }
            }
        }
    }

    #[test]
    fn rounds_log2() {
        assert_eq!(tree_rounds(1), 0);
        assert_eq!(tree_rounds(2), 1);
        assert_eq!(tree_rounds(8), 3);
        assert_eq!(tree_rounds(9), 4);
        assert_eq!(tree_rounds(128), 7);
    }

    #[test]
    fn subtree_partition() {
        // The root's subtree is everyone; subtrees of the root's children
        // partition the non-root ranks.
        let (n, root) = (16, 3);
        let all = binomial_subtree(root, root, n);
        let mut sorted = all.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..n).collect::<Vec<_>>());
        let (_, children) = binomial_bcast(root, root, n);
        let mut seen = vec![false; n];
        seen[root] = true;
        for c in children {
            for r in binomial_subtree(c.peer, root, n) {
                assert!(!seen[r], "rank {r} in two subtrees");
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn subtree_iterative_matches_tree_children() {
        // The accumulator walk must enumerate exactly the ranks whose
        // bcast recv-parent chain passes through `rank`, with the rank
        // itself first, for every shape and root.
        for n in [1usize, 2, 5, 8, 13, 16, 33] {
            for root in [0, n / 2, n - 1] {
                for rank in 0..n {
                    let sub = binomial_subtree(rank, root, n);
                    assert_eq!(sub[0], rank, "own rank leads");
                    let mut inset = vec![false; n];
                    for &r in &sub {
                        assert!(!inset[r], "duplicate {r}");
                        inset[r] = true;
                    }
                    // Membership check: walk each rank's parent chain.
                    for r in 0..n {
                        let mut cur = r;
                        let mut through = false;
                        loop {
                            if cur == rank {
                                through = true;
                                break;
                            }
                            match binomial_bcast(cur, root, n).0 {
                                Some(step) => cur = step.peer,
                                None => break,
                            }
                        }
                        assert_eq!(inset[r], through, "n={n} root={root} rank={rank} r={r}");
                    }
                }
            }
        }
    }

    #[test]
    fn subtree_into_appends_without_clearing() {
        let mut out = vec![99usize];
        binomial_subtree_into(0, 0, 4, &mut out);
        assert_eq!(out[0], 99);
        assert_eq!(out[1], 0);
        assert_eq!(out.len(), 5);
    }

    #[test]
    fn topology_from_map_and_accessors() {
        let t = Topology::from_map(vec![0, 0, 1, 1, 1, 2]).unwrap();
        assert_eq!(t.ranks(), 6);
        assert_eq!(t.nodes(), 3);
        assert_eq!(t.members(1), &[2, 3, 4]);
        assert_eq!(t.leaders(), &[0, 2, 5]);
        assert!(t.is_leader(2) && !t.is_leader(3));
        assert_eq!(t.leader_of(4), 2);
        assert_eq!(t.local_index(4), 2);
        assert_eq!(t.link_class(0, 1), LinkClass::Intra);
        assert_eq!(t.link_class(1, 2), LinkClass::Inter);
        assert_eq!(t.link_class(3, 3), LinkClass::Intra);
        assert!(t.is_hierarchical());
    }

    #[test]
    fn topology_shapes() {
        let flat = Topology::flat(5);
        assert_eq!(flat.nodes(), 5);
        assert!(!flat.is_hierarchical());
        assert_eq!(flat.leaders(), &[0, 1, 2, 3, 4]);

        let blocked = Topology::blocked(3, 4);
        assert_eq!(blocked.ranks(), 12);
        assert_eq!(blocked.node_of(7), 1);
        assert_eq!(blocked.leaders(), &[0, 4, 8]);

        let grouped = Topology::grouped(&[3, 1, 2]).unwrap();
        assert_eq!(grouped.members(0), &[0, 1, 2]);
        assert_eq!(grouped.members(1), &[3]);
        assert_eq!(grouped.members(2), &[4, 5]);

        assert!(Topology::from_map(vec![0, 2]).is_err(), "gap in node ids");
        assert!(Topology::from_map(Vec::new()).is_err());
        assert!(Topology::grouped(&[2, 0]).is_err());
    }

    #[test]
    fn group_mapped_schedules_translate_ranks() {
        let group = [3usize, 7, 11, 15];
        let nb = ring_in_group(&group, 0);
        assert_eq!(nb.next, 7);
        assert_eq!(nb.prev, 15);
        // Group binomial must be the flat binomial with peers mapped.
        for idx in 0..group.len() {
            let (recv, sends) = binomial_bcast_in_group(&group, idx, 1);
            let (frecv, fsends) = binomial_bcast(idx, 1, group.len());
            assert_eq!(recv.map(|s| s.peer), frecv.map(|s| group[s.peer]));
            assert_eq!(recv.map(|s| s.round), frecv.map(|s| s.round));
            let mapped: Vec<usize> = fsends.iter().map(|s| group[s.peer]).collect();
            let got: Vec<usize> = sends.iter().map(|s| s.peer).collect();
            assert_eq!(got, mapped);
        }
    }
}
