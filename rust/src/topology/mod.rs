//! Communication schedules: ring and binomial tree.
//!
//! These are the two algorithm families the paper integrates compression
//! into — the ring (allgather / reduce-scatter, §3.1.1–3.1.2) and the
//! MPICH binomial tree (bcast / scatter, §4.5).

/// Ring neighbours of `rank` in a communicator of `n`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RingNeighbors {
    /// Rank we send to (`rank + 1`).
    pub next: usize,
    /// Rank we receive from (`rank - 1`).
    pub prev: usize,
}

/// Ring neighbours.
pub fn ring(rank: usize, n: usize) -> RingNeighbors {
    debug_assert!(rank < n && n > 0);
    RingNeighbors { next: (rank + 1) % n, prev: (rank + n - 1) % n }
}

/// In the standard ring schedule, the chunk that `rank` *sends* in round
/// `round` (0-based) of an allgather / the chunk it contributes in
/// reduce-scatter.
pub fn ring_send_chunk(rank: usize, round: usize, n: usize) -> usize {
    (rank + n - round % n) % n
}

/// The chunk `rank` *receives* in round `round` of the ring schedule.
pub fn ring_recv_chunk(rank: usize, round: usize, n: usize) -> usize {
    (rank + n - round % n - 1) % n
}

/// One step of a binomial-tree schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TreeStep {
    /// Round index (0-based; round `k` spans distance `2^k` in the
    /// standard MPICH formulation counting down from the top bit).
    pub round: usize,
    /// Peer rank for this step.
    pub peer: usize,
}

/// Binomial-tree broadcast schedule for `rank` rooted at `root`.
///
/// Returns `(recv_from, sends)`: the (at most one) parent this rank
/// receives from, then the ordered list of children it forwards to.
/// Matches MPICH's `MPIR_Bcast_intra_binomial`: relative rank
/// `vrank = (rank - root) mod n`; in the receiving phase the mask grows
/// from 1, in the sending phase it shrinks back down.
pub fn binomial_bcast(rank: usize, root: usize, n: usize) -> (Option<TreeStep>, Vec<TreeStep>) {
    debug_assert!(rank < n && root < n && n > 0);
    let vrank = (rank + n - root) % n;
    let logtop = tree_rounds(n);
    // Receive phase: the lowest set bit of vrank names the parent; the
    // round is the step at which the parent reaches this subtree (the root
    // sends its largest-mask child first, at round 0).
    let mut recv = None;
    let mut mask = 1usize;
    while mask < n {
        if vrank & mask != 0 {
            let vpeer = vrank - mask;
            let round = logtop - 1 - mask.trailing_zeros() as usize;
            recv = Some(TreeStep { round, peer: (vpeer + root) % n });
            break;
        }
        mask <<= 1;
    }
    if vrank == 0 {
        mask = 1usize << logtop;
    }
    // Send phase (MPICH mask-halving): children get masks below our own
    // lowest set bit, largest (earliest round) first.
    let mut sends = Vec::new();
    let mut m = mask >> 1;
    while m > 0 {
        let vchild = vrank + m;
        if vchild < n {
            sends.push(TreeStep {
                round: logtop - 1 - m.trailing_zeros() as usize,
                peer: (vchild + root) % n,
            });
        }
        m >>= 1;
    }
    (recv, sends)
}

/// Number of rounds a binomial tree takes over `n` ranks (`ceil(log2 n)`).
pub fn tree_rounds(n: usize) -> usize {
    if n <= 1 {
        0
    } else {
        usize::BITS as usize - (n - 1).leading_zeros() as usize
    }
}

/// The set of descendant ranks of `rank` in the binomial scatter tree
/// rooted at `root` (the ranks whose data must flow through `rank`),
/// including `rank` itself. Used by Z-Scatter to forward only the needed
/// compressed chunks.
pub fn binomial_subtree(rank: usize, root: usize, n: usize) -> Vec<usize> {
    let (_, sends) = binomial_bcast(rank, root, n);
    let mut out = vec![rank];
    for s in sends {
        out.extend(binomial_subtree(s.peer, root, n));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_chunks_cover_everything() {
        // Over n-1 rounds of the allgather schedule, each rank receives all
        // chunks except its own.
        let n = 8;
        for rank in 0..n {
            let mut got = vec![false; n];
            got[rank] = true;
            for round in 0..n - 1 {
                let c = ring_recv_chunk(rank, round, n);
                assert!(!got[c], "duplicate chunk {c} at rank {rank} round {round}");
                got[c] = true;
            }
            assert!(got.iter().all(|&g| g));
        }
    }

    #[test]
    fn ring_send_matches_prev_recv() {
        // What rank r sends in round t is what rank r+1 receives in round t.
        let n = 7;
        for rank in 0..n {
            for round in 0..n - 1 {
                let sent = ring_send_chunk(rank, round, n);
                let recv = ring_recv_chunk((rank + 1) % n, round, n);
                assert_eq!(sent, recv);
            }
        }
    }

    #[test]
    fn binomial_reaches_everyone_once() {
        for n in [1usize, 2, 3, 4, 5, 8, 13, 16, 64, 100] {
            for root in [0, n / 2, n - 1] {
                let mut received = vec![0usize; n];
                received[root] += 1; // root starts with the data
                for rank in 0..n {
                    let (recv, _) = binomial_bcast(rank, root, n);
                    if let Some(r) = recv {
                        assert_ne!(rank, root, "root must not receive");
                        let _ = r;
                        received[rank] += 1;
                    }
                }
                for (rank, &c) in received.iter().enumerate() {
                    assert_eq!(c, 1, "rank {rank} n {n} root {root}");
                }
            }
        }
    }

    #[test]
    fn binomial_send_recv_pair_up() {
        // Every child's recv step must appear in its parent's send list
        // with the same round.
        for n in [2usize, 5, 8, 16, 33] {
            let root = 1 % n;
            for rank in 0..n {
                let (recv, _) = binomial_bcast(rank, root, n);
                if let Some(step) = recv {
                    let (_, parent_sends) = binomial_bcast(step.peer, root, n);
                    assert!(
                        parent_sends.iter().any(|s| s.peer == rank && s.round == step.round),
                        "n={n} rank={rank} parent={} round={}",
                        step.peer,
                        step.round
                    );
                }
            }
        }
    }

    #[test]
    fn rounds_log2() {
        assert_eq!(tree_rounds(1), 0);
        assert_eq!(tree_rounds(2), 1);
        assert_eq!(tree_rounds(8), 3);
        assert_eq!(tree_rounds(9), 4);
        assert_eq!(tree_rounds(128), 7);
    }

    #[test]
    fn subtree_partition() {
        // The root's subtree is everyone; subtrees of the root's children
        // partition the non-root ranks.
        let (n, root) = (16, 3);
        let all = binomial_subtree(root, root, n);
        let mut sorted = all.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..n).collect::<Vec<_>>());
        let (_, children) = binomial_bcast(root, root, n);
        let mut seen = vec![false; n];
        seen[root] = true;
        for c in children {
            for r in binomial_subtree(c.peer, root, n) {
                assert!(!seen[r], "rank {r} in two subtrees");
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
