//! Unified error type for the crate.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error type covering every subsystem.
#[derive(Debug)]
pub enum Error {
    /// Malformed or truncated compressed stream.
    Corrupt(String),
    /// Invalid argument / configuration.
    Invalid(String),
    /// Transport-level failure (peer gone, channel closed, socket error).
    Transport(String),
    /// PJRT / XLA runtime failure.
    Runtime(String),
    /// Underlying I/O error.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Corrupt(m) => write!(f, "corrupt stream: {m}"),
            Error::Invalid(m) => write!(f, "invalid argument: {m}"),
            Error::Transport(m) => write!(f, "transport: {m}"),
            Error::Runtime(m) => write!(f, "runtime: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand constructor for [`Error::Corrupt`].
    pub fn corrupt(m: impl Into<String>) -> Self {
        Error::Corrupt(m.into())
    }
    /// Shorthand constructor for [`Error::Invalid`].
    pub fn invalid(m: impl Into<String>) -> Self {
        Error::Invalid(m.into())
    }
    /// Shorthand constructor for [`Error::Transport`].
    pub fn transport(m: impl Into<String>) -> Self {
        Error::Transport(m.into())
    }
    /// Shorthand constructor for [`Error::Runtime`].
    pub fn runtime(m: impl Into<String>) -> Self {
        Error::Runtime(m.into())
    }
}
