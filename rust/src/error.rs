//! Unified error type for the crate.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error type covering every subsystem.
#[derive(Debug)]
pub enum Error {
    /// Malformed or truncated compressed stream.
    Corrupt(String),
    /// Invalid argument / configuration.
    Invalid(String),
    /// Transport-level failure (peer gone, channel closed, socket error).
    Transport(String),
    /// PJRT / XLA runtime failure.
    Runtime(String),
    /// Underlying I/O error.
    Io(std::io::Error),
    /// A deadline expired while receives were still outstanding. `pending`
    /// lists exactly which `(source rank, tag)` matches never arrived, so
    /// a hung collective names the peers it was waiting on.
    Timeout {
        /// The `(source rank, tag)` receives still pending at expiry.
        pending: Vec<(usize, u64)>,
    },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Corrupt(m) => write!(f, "corrupt stream: {m}"),
            Error::Invalid(m) => write!(f, "invalid argument: {m}"),
            Error::Transport(m) => write!(f, "transport: {m}"),
            Error::Runtime(m) => write!(f, "runtime: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Timeout { pending } => {
                write!(f, "timeout: {} receive(s) still pending", pending.len())?;
                for (i, (rank, tag)) in pending.iter().take(8).enumerate() {
                    let sep = if i == 0 { ": " } else { ", " };
                    write!(f, "{sep}(rank {rank}, tag {tag})")?;
                }
                if pending.len() > 8 {
                    write!(f, ", ... ({} more)", pending.len() - 8)?;
                }
                Ok(())
            }
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand constructor for [`Error::Corrupt`].
    pub fn corrupt(m: impl Into<String>) -> Self {
        Error::Corrupt(m.into())
    }
    /// Shorthand constructor for [`Error::Invalid`].
    pub fn invalid(m: impl Into<String>) -> Self {
        Error::Invalid(m.into())
    }
    /// Shorthand constructor for [`Error::Transport`].
    pub fn transport(m: impl Into<String>) -> Self {
        Error::Transport(m.into())
    }
    /// Shorthand constructor for [`Error::Runtime`].
    pub fn runtime(m: impl Into<String>) -> Self {
        Error::Runtime(m.into())
    }
    /// Shorthand constructor for [`Error::Timeout`].
    pub fn timeout(pending: Vec<(usize, u64)>) -> Self {
        Error::Timeout { pending }
    }
    /// Whether retrying the operation (with the same peers) can succeed.
    /// Only [`Error::Timeout`] is recoverable: the peers may merely be
    /// slow. Corruption, transport failure, and invalid arguments are
    /// permanent for this communicator.
    pub fn is_recoverable(&self) -> bool {
        matches!(self, Error::Timeout { .. })
    }
}
