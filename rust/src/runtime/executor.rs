//! PJRT executor: compile HLO-text artifacts and run them.

use std::path::Path;

use crate::runtime::manifest::{ArtifactSpec, Manifest, TensorSpec};
use crate::{Error, Result};

fn xerr(e: xla::Error) -> Error {
    Error::runtime(e.to_string())
}

/// A PJRT client bound to the host CPU.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu().map_err(xerr)? })
    }

    /// Platform string (for `zccl info`).
    pub fn platform(&self) -> String {
        format!("{} ({} devices)", self.client.platform_name(), self.client.device_count())
    }

    /// Compile one artifact from its HLO text file.
    pub fn compile(&self, dir: impl AsRef<Path>, spec: &ArtifactSpec) -> Result<Module> {
        let path = dir.as_ref().join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::invalid("non-utf8 artifact path"))?,
        )
        .map_err(xerr)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(xerr)?;
        Ok(Module { exe, spec: spec.clone() })
    }

    /// Convenience: load the manifest and compile `name`.
    pub fn load(&self, dir: impl AsRef<Path>, name: &str) -> Result<Module> {
        let manifest = Manifest::load(&dir)?;
        let spec = manifest.artifact(name)?;
        self.compile(&dir, spec)
    }
}

/// One compiled artifact ready to execute.
pub struct Module {
    exe: xla::PjRtLoadedExecutable,
    /// The artifact's signature (used for input validation).
    pub spec: ArtifactSpec,
}

impl Module {
    /// Execute with the given inputs (must match the manifest signature
    /// arity). Returns the untupled outputs.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::invalid(format!(
                "artifact {}: {} inputs given, {} expected",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            )));
        }
        let result = self.exe.execute::<xla::Literal>(inputs).map_err(xerr)?;
        let lit = result[0][0].to_literal_sync().map_err(xerr)?;
        // aot.py lowers with return_tuple=True: always a tuple.
        lit.to_tuple().map_err(xerr)
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(values: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != values.len() {
        return Err(Error::invalid(format!("literal shape {shape:?} != {} values", values.len())));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(values).reshape(&dims).map_err(xerr)
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(values: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != values.len() {
        return Err(Error::invalid(format!("literal shape {shape:?} != {} values", values.len())));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(values).reshape(&dims).map_err(xerr)
}

/// Extract an f32 literal's values.
pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(xerr)
}

/// Validate that a literal matches a manifest tensor spec (debug aid).
pub fn check_spec(lit: &xla::Literal, spec: &TensorSpec) -> Result<()> {
    if lit.element_count() != spec.elements() {
        return Err(Error::invalid(format!(
            "literal has {} elements, spec {:?} wants {}",
            lit.element_count(),
            spec.shape,
            spec.elements()
        )));
    }
    Ok(())
}
