//! PJRT executor: compile HLO-text artifacts and run them.
//!
//! The real executor needs the external `xla` crate, which not every
//! build environment vendors. With the `pjrt` cargo feature the genuine
//! PJRT path compiles; without it this module provides an API-compatible
//! stub whose [`Runtime::cpu`] fails with a clear message — everything
//! that does not touch PJRT (all compressors, collectives, benches)
//! builds and runs identically either way.

#[cfg(feature = "pjrt")]
mod real {
    use std::path::Path;

    use crate::runtime::manifest::{ArtifactSpec, Manifest, TensorSpec};
    use crate::{Error, Result};

    /// The tensor/literal type handed to [`Module::run`].
    pub use xla::Literal;

    fn xerr(e: xla::Error) -> Error {
        Error::runtime(e.to_string())
    }

    /// A PJRT client bound to the host CPU.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        /// Whether this build carries the real PJRT executor.
        pub fn available() -> bool {
            true
        }

        /// Create the CPU PJRT client.
        pub fn cpu() -> Result<Runtime> {
            Ok(Runtime { client: xla::PjRtClient::cpu().map_err(xerr)? })
        }

        /// Platform string (for `zccl info`).
        pub fn platform(&self) -> String {
            format!("{} ({} devices)", self.client.platform_name(), self.client.device_count())
        }

        /// Compile one artifact from its HLO text file.
        pub fn compile(&self, dir: impl AsRef<Path>, spec: &ArtifactSpec) -> Result<Module> {
            let path = dir.as_ref().join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::invalid("non-utf8 artifact path"))?,
            )
            .map_err(xerr)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(xerr)?;
            Ok(Module { exe, spec: spec.clone() })
        }

        /// Convenience: load the manifest and compile `name`.
        pub fn load(&self, dir: impl AsRef<Path>, name: &str) -> Result<Module> {
            let manifest = Manifest::load(&dir)?;
            let spec = manifest.artifact(name)?;
            self.compile(&dir, spec)
        }
    }

    /// One compiled artifact ready to execute.
    pub struct Module {
        exe: xla::PjRtLoadedExecutable,
        /// The artifact's signature (used for input validation).
        pub spec: ArtifactSpec,
    }

    impl Module {
        /// Execute with the given inputs (must match the manifest signature
        /// arity). Returns the untupled outputs.
        pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
            if inputs.len() != self.spec.inputs.len() {
                return Err(Error::invalid(format!(
                    "artifact {}: {} inputs given, {} expected",
                    self.spec.name,
                    inputs.len(),
                    self.spec.inputs.len()
                )));
            }
            let result = self.exe.execute::<Literal>(inputs).map_err(xerr)?;
            let lit = result[0][0].to_literal_sync().map_err(xerr)?;
            // aot.py lowers with return_tuple=True: always a tuple.
            lit.to_tuple().map_err(xerr)
        }
    }

    /// Build an f32 literal of the given shape.
    pub fn literal_f32(values: &[f32], shape: &[usize]) -> Result<Literal> {
        let n: usize = shape.iter().product();
        if n != values.len() {
            return Err(Error::invalid(format!(
                "literal shape {shape:?} != {} values",
                values.len()
            )));
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Literal::vec1(values).reshape(&dims).map_err(xerr)
    }

    /// Build an i32 literal of the given shape.
    pub fn literal_i32(values: &[i32], shape: &[usize]) -> Result<Literal> {
        let n: usize = shape.iter().product();
        if n != values.len() {
            return Err(Error::invalid(format!(
                "literal shape {shape:?} != {} values",
                values.len()
            )));
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Literal::vec1(values).reshape(&dims).map_err(xerr)
    }

    /// Extract an f32 literal's values.
    pub fn literal_to_f32(lit: &Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(xerr)
    }

    /// Validate that a literal matches a manifest tensor spec (debug aid).
    pub fn check_spec(lit: &Literal, spec: &TensorSpec) -> Result<()> {
        if lit.element_count() != spec.elements() {
            return Err(Error::invalid(format!(
                "literal has {} elements, spec {:?} wants {}",
                lit.element_count(),
                spec.shape,
                spec.elements()
            )));
        }
        Ok(())
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::Path;

    use crate::runtime::manifest::{ArtifactSpec, TensorSpec};
    use crate::{Error, Result};

    const MSG: &str = "built without the 'pjrt' feature: the PJRT/XLA runtime is stubbed \
                       (enable feature `pjrt` and provide the `xla` crate)";

    /// Opaque stand-in for `xla::Literal`.
    #[derive(Debug, Clone)]
    pub struct Literal;

    impl Literal {
        /// Mirrors `xla::Literal::to_vec`; always fails in a stub build.
        pub fn to_vec<T>(&self) -> Result<Vec<T>> {
            Err(Error::runtime(MSG))
        }
    }

    /// Stubbed PJRT client; every constructor fails.
    pub struct Runtime;

    impl Runtime {
        /// Whether this build carries the real PJRT executor.
        pub fn available() -> bool {
            false
        }

        /// Always fails in a stub build.
        pub fn cpu() -> Result<Runtime> {
            Err(Error::runtime(MSG))
        }

        /// Platform string (never reached in practice — `cpu()` fails).
        pub fn platform(&self) -> String {
            "pjrt-stub (0 devices)".into()
        }

        /// Always fails in a stub build.
        pub fn compile(&self, _dir: impl AsRef<Path>, _spec: &ArtifactSpec) -> Result<Module> {
            Err(Error::runtime(MSG))
        }

        /// Always fails in a stub build.
        pub fn load(&self, _dir: impl AsRef<Path>, _name: &str) -> Result<Module> {
            Err(Error::runtime(MSG))
        }
    }

    /// Stubbed compiled artifact (cannot be constructed via [`Runtime`]).
    pub struct Module {
        /// The artifact's signature.
        pub spec: ArtifactSpec,
    }

    impl Module {
        /// Always fails in a stub build.
        pub fn run(&self, _inputs: &[Literal]) -> Result<Vec<Literal>> {
            Err(Error::runtime(MSG))
        }
    }

    /// Always fails in a stub build.
    pub fn literal_f32(_values: &[f32], _shape: &[usize]) -> Result<Literal> {
        Err(Error::runtime(MSG))
    }

    /// Always fails in a stub build.
    pub fn literal_i32(_values: &[i32], _shape: &[usize]) -> Result<Literal> {
        Err(Error::runtime(MSG))
    }

    /// Always fails in a stub build.
    pub fn literal_to_f32(_lit: &Literal) -> Result<Vec<f32>> {
        Err(Error::runtime(MSG))
    }

    /// Always fails in a stub build.
    pub fn check_spec(_lit: &Literal, _spec: &TensorSpec) -> Result<()> {
        Err(Error::runtime(MSG))
    }
}

#[cfg(feature = "pjrt")]
pub use real::{check_spec, literal_f32, literal_i32, literal_to_f32, Literal, Module, Runtime};
#[cfg(not(feature = "pjrt"))]
pub use stub::{check_spec, literal_f32, literal_i32, literal_to_f32, Literal, Module, Runtime};
