//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` runs Python ONCE to lower the L2 model (+ L1 Pallas
//! kernel) to HLO text plus a `manifest.json`; this module is the L3 side:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. Python never runs on the request path —
//! after `make artifacts` the Rust binary is self-contained.

pub mod executor;
pub mod manifest;

pub use executor::{check_spec, literal_f32, literal_i32, literal_to_f32, Literal, Module, Runtime};
pub use manifest::{ArtifactSpec, Manifest, ParamSpec, TensorSpec};
