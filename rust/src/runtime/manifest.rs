//! `manifest.json` parsing (emitted by `python/compile/aot.py`).

use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::{Error, Result};

/// One tensor's shape + dtype.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Dimensions (row-major).
    pub shape: Vec<usize>,
    /// Dtype name as jax prints it (`float32`, `int32`).
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered artifact (an HLO text file + its signature).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Artifact name (`grad_step`, `lorenzo_quant`, ...).
    pub name: String,
    /// HLO text filename relative to the artifact dir.
    pub file: String,
    /// Input signature in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output signature (the HLO returns a tuple in this order).
    pub outputs: Vec<TensorSpec>,
}

/// One initial-parameter table entry (into `params.bin`).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    /// Parameter name (`l0.attn.wqkv`, ...).
    pub name: String,
    /// Shape.
    pub shape: Vec<usize>,
    /// Byte offset in `params.bin`.
    pub offset: usize,
    /// Byte length.
    pub bytes: usize,
}

/// Transformer dimensions recorded by aot.py.
#[derive(Debug, Clone, Copy, Default)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub seq: usize,
    pub batch: usize,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Model dimensions.
    pub config: ModelConfig,
    /// The error bound baked into `grad_step_zccl`.
    pub grad_eb: f64,
    /// Lowered artifacts.
    pub artifacts: Vec<ArtifactSpec>,
    /// Initial parameter table.
    pub params: Vec<ParamSpec>,
}

fn tensor_spec(j: &Json) -> Result<TensorSpec> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::invalid("tensor spec missing shape"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| Error::invalid("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = j
        .get("dtype")
        .and_then(Json::as_str)
        .ok_or_else(|| Error::invalid("tensor spec missing dtype"))?
        .to_string();
    Ok(TensorSpec { shape, dtype })
}

impl Manifest {
    /// Load `manifest.json` from `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let j = Json::parse(&text)?;
        if j.get("version").and_then(Json::as_usize) != Some(1) {
            return Err(Error::invalid("unsupported manifest version"));
        }
        let cfgj = j.get("config").ok_or_else(|| Error::invalid("manifest missing config"))?;
        let dim = |k: &str| -> Result<usize> {
            cfgj.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::invalid(format!("config missing {k}")))
        };
        let config = ModelConfig {
            vocab: dim("vocab")?,
            d_model: dim("d_model")?,
            n_heads: dim("n_heads")?,
            n_layers: dim("n_layers")?,
            seq: dim("seq")?,
            batch: dim("batch")?,
        };
        let grad_eb = j.get("grad_eb").and_then(Json::as_f64).unwrap_or(1e-4);
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::invalid("manifest missing artifacts"))?
        {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::invalid("artifact missing name"))?
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::invalid("artifact missing file"))?
                .to_string();
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::invalid("artifact missing inputs"))?
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::invalid("artifact missing outputs"))?
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactSpec { name, file, inputs, outputs });
        }
        let mut params = Vec::new();
        for p in j.get("params").and_then(Json::as_arr).unwrap_or(&[]) {
            params.push(ParamSpec {
                name: p
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::invalid("param missing name"))?
                    .to_string(),
                shape: p
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| Error::invalid("param missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| Error::invalid("bad dim")))
                    .collect::<Result<Vec<_>>>()?,
                offset: p
                    .get("offset")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| Error::invalid("param missing offset"))?,
                bytes: p
                    .get("bytes")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| Error::invalid("param missing bytes"))?,
            });
        }
        Ok(Manifest { dir, config, grad_eb, artifacts, params })
    }

    /// Find an artifact by name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| Error::invalid(format!("no artifact '{name}' in manifest")))
    }

    /// Load the initial parameters from `params.bin` as `(name, shape,
    /// values)` triples in manifest order.
    pub fn load_params(&self) -> Result<Vec<(String, Vec<usize>, Vec<f32>)>> {
        let blob = std::fs::read(self.dir.join("params.bin"))?;
        let mut out = Vec::with_capacity(self.params.len());
        for p in &self.params {
            let end = p.offset + p.bytes;
            let b = blob
                .get(p.offset..end)
                .ok_or_else(|| Error::corrupt(format!("params.bin short for {}", p.name)))?;
            let vals: Vec<f32> =
                b.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
            if vals.len() != p.shape.iter().product::<usize>() {
                return Err(Error::corrupt(format!("param {} size mismatch", p.name)));
            }
            out.push((p.name.clone(), p.shape.clone(), vals));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join(format!("zccl-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "preset": "tiny", "grad_eb": 0.001,
                "config": {"vocab": 8, "d_model": 4, "n_heads": 2, "n_layers": 1, "seq": 4, "batch": 2},
                "artifacts": [{"name": "m", "file": "m.hlo.txt",
                  "inputs": [{"shape": [2, 4], "dtype": "int32"}],
                  "outputs": [{"shape": [], "dtype": "float32"}]}],
                "params": [{"name": "w", "shape": [2, 2], "offset": 0, "bytes": 16}]}"#,
        )
        .unwrap();
        std::fs::write(
            dir.join("params.bin"),
            [1f32, 2.0, 3.0, 4.0].iter().flat_map(|v| v.to_le_bytes()).collect::<Vec<u8>>(),
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.config.vocab, 8);
        assert_eq!(m.grad_eb, 0.001);
        let a = m.artifact("m").unwrap();
        assert_eq!(a.inputs[0].shape, vec![2, 4]);
        assert_eq!(a.outputs[0].elements(), 1);
        let params = m.load_params().unwrap();
        assert_eq!(params[0].2, vec![1.0, 2.0, 3.0, 4.0]);
        assert!(m.artifact("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
