//! Minimal data-parallel helpers over `std::thread::scope`.
//!
//! The multi-thread compression mode only needs "map a function over the
//! chunks of a slice, in parallel, preserving order" — this module
//! provides exactly that with a work-stealing-free atomic cursor.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every element of `items`, in parallel across `threads`
/// workers, returning results in input order.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut parts: Vec<Vec<(usize, R)>> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let cursor = &cursor;
                let f = &f;
                s.spawn(move || {
                    let mut local = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, f(i, &items[i])));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            parts.push(h.join().expect("par_map worker panicked"));
        }
    });
    let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    for part in parts {
        for (i, r) in part {
            slots[i] = Some(r);
        }
    }
    slots.into_iter().map(|s| s.expect("all indices produced")).collect()
}

/// Parallel map over the `chunk`-sized pieces of `data` (last piece may be
/// short), preserving order.
pub fn par_map_chunks<R, F>(data: &[f32], chunk: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(&[f32]) -> R + Sync,
{
    let pieces: Vec<&[f32]> = data.chunks(chunk.max(1)).collect();
    par_map(&pieces, threads, |_, p| f(p))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = par_map(&items, 4, |_, &x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let items = vec![1, 2, 3];
        assert_eq!(par_map(&items, 1, |i, &x| x + i), vec![1, 3, 5]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u8> = vec![];
        let out: Vec<u8> = par_map(&items, 4, |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn chunk_map() {
        let data: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let sums = par_map_chunks(&data, 4, 2, |c| c.iter().sum::<f32>());
        assert_eq!(sums, vec![6.0, 22.0, 17.0]); // [0..4), [4..8), [8..10)
    }
}
