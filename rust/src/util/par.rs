//! Minimal data-parallel helpers over `std::thread::scope`.
//!
//! The multi-thread compression mode only needs "map a function over the
//! chunks of a slice, in parallel, preserving order" — this module
//! provides exactly that, dispatching work items through a mutex-guarded
//! iterator (the per-item critical section is one `next()` call,
//! negligible against a chunk's codec cost).

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every element of `items`, in parallel across `threads`
/// workers, returning results in input order. Thin borrow adapter over
/// [`par_map_own`].
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    par_map_own(items.iter().collect(), threads, |i, t| f(i, t))
}

/// Like [`par_map`] but consuming the items, so workers receive them **by
/// value** — the shape needed to hand each worker a disjoint `&mut` slice
/// (e.g. the multithread fused decompress–reduce kernel folding chunks
/// into non-overlapping accumulator windows). Results come back in input
/// order.
pub fn par_map_own<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let queue = std::sync::Mutex::new(items.into_iter().enumerate());
    let mut parts: Vec<Vec<(usize, R)>> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let queue = &queue;
                let f = &f;
                s.spawn(move || {
                    let mut local = Vec::new();
                    loop {
                        // The guard drops at the end of this statement, so
                        // the lock is NOT held while `f` runs.
                        let next = queue.lock().expect("par_map_own queue poisoned").next();
                        let Some((i, t)) = next else { break };
                        local.push((i, f(i, t)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            parts.push(h.join().expect("par_map_own worker panicked"));
        }
    });
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for part in parts {
        for (i, r) in part {
            slots[i] = Some(r);
        }
    }
    slots.into_iter().map(|s| s.expect("all indices produced")).collect()
}

/// Parallel map over the `chunk`-sized pieces of `data` (last piece may be
/// short), preserving order.
pub fn par_map_chunks<R, F>(data: &[f32], chunk: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(&[f32]) -> R + Sync,
{
    let pieces: Vec<&[f32]> = data.chunks(chunk.max(1)).collect();
    par_map(&pieces, threads, |_, p| f(p))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = par_map(&items, 4, |_, &x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let items = vec![1, 2, 3];
        assert_eq!(par_map(&items, 1, |i, &x| x + i), vec![1, 3, 5]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u8> = vec![];
        let out: Vec<u8> = par_map(&items, 4, |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn own_map_feeds_mut_slices() {
        let mut data = vec![0u32; 64];
        let pieces: Vec<(usize, &mut [u32])> = data.chunks_mut(16).enumerate().collect();
        let lens = par_map_own(pieces, 4, |_, (base, piece)| {
            for (k, v) in piece.iter_mut().enumerate() {
                *v = (base * 16 + k) as u32;
            }
            piece.len()
        });
        assert_eq!(lens, vec![16, 16, 16, 16]);
        assert_eq!(data, (0..64).collect::<Vec<u32>>());
    }

    #[test]
    fn chunk_map() {
        let data: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let sums = par_map_chunks(&data, 4, 2, |c| c.iter().sum::<f32>());
        assert_eq!(sums, vec![6.0, 22.0, 17.0]); // [0..4), [4..8), [8..10)
    }
}
