//! Minimal JSON: a writer for results files and a recursive-descent parser
//! for the AOT artifact manifest. Supports the JSON subset both sides
//! emit: objects, arrays, strings (with escapes), numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// Boolean.
    Bool(bool),
    /// All numbers are f64 (the manifest only carries small ints).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys for deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object constructor from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    /// Field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// String access.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Numeric access.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// Integer access (exact f64s only).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| n.fract() == 0.0 && *n >= 0.0).map(|n| n as usize)
    }
    /// Array access.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialise compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let b = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            return Err(Error::invalid(format!("trailing JSON at byte {pos}")));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err(Error::invalid("unexpected end of JSON")),
        Some(b'{') => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos)? {
                    Json::Str(s) => s,
                    _ => return Err(Error::invalid("object key must be a string")),
                };
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(Error::invalid("expected ':'"));
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                m.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(m));
                    }
                    _ => return Err(Error::invalid("expected ',' or '}'")),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut a = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(a));
            }
            loop {
                a.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(a));
                    }
                    _ => return Err(Error::invalid("expected ',' or ']'")),
                }
            }
        }
        Some(b'"') => {
            *pos += 1;
            let mut s = String::new();
            loop {
                match b.get(*pos) {
                    None => return Err(Error::invalid("unterminated string")),
                    Some(b'"') => {
                        *pos += 1;
                        return Ok(Json::Str(s));
                    }
                    Some(b'\\') => {
                        *pos += 1;
                        match b.get(*pos) {
                            Some(b'"') => s.push('"'),
                            Some(b'\\') => s.push('\\'),
                            Some(b'/') => s.push('/'),
                            Some(b'n') => s.push('\n'),
                            Some(b'r') => s.push('\r'),
                            Some(b't') => s.push('\t'),
                            Some(b'b') => s.push('\u{8}'),
                            Some(b'f') => s.push('\u{c}'),
                            Some(b'u') => {
                                let hex = b
                                    .get(*pos + 1..*pos + 5)
                                    .ok_or_else(|| Error::invalid("bad \\u escape"))?;
                                let code = u32::from_str_radix(
                                    std::str::from_utf8(hex)
                                        .map_err(|_| Error::invalid("bad \\u escape"))?,
                                    16,
                                )
                                .map_err(|_| Error::invalid("bad \\u escape"))?;
                                s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                                *pos += 4;
                            }
                            _ => return Err(Error::invalid("bad escape")),
                        }
                        *pos += 1;
                    }
                    Some(&c) => {
                        // Collect a UTF-8 run.
                        let start = *pos;
                        let mut end = *pos + 1;
                        if c < 0x80 {
                            while end < b.len()
                                && b[end] != b'"'
                                && b[end] != b'\\'
                                && b[end] < 0x80
                            {
                                end += 1;
                            }
                        } else {
                            while end < b.len() && b[end] >= 0x80 {
                                end += 1;
                            }
                        }
                        s.push_str(
                            std::str::from_utf8(&b[start..end])
                                .map_err(|_| Error::invalid("invalid utf-8 in string"))?,
                        );
                        *pos = end;
                    }
                }
            }
        }
        Some(b't') => {
            expect(b, pos, "true")?;
            Ok(Json::Bool(true))
        }
        Some(b'f') => {
            expect(b, pos, "false")?;
            Ok(Json::Bool(false))
        }
        Some(b'n') => {
            expect(b, pos, "null")?;
            Ok(Json::Null)
        }
        Some(_) => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            let text = std::str::from_utf8(&b[start..*pos]).unwrap();
            text.parse::<f64>()
                .map(Json::Num)
                .map_err(|_| Error::invalid(format!("bad number '{text}'")))
        }
    }
}

fn expect(b: &[u8], pos: &mut usize, word: &str) -> Result<()> {
    if b.get(*pos..*pos + word.len()) == Some(word.as_bytes()) {
        *pos += word.len();
        Ok(())
    } else {
        Err(Error::invalid(format!("expected '{word}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_document() {
        let doc = Json::obj(vec![
            ("name", Json::Str("train_step".into())),
            ("n", Json::Num(3.0)),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            (
                "shapes",
                Json::Arr(vec![
                    Json::Arr(vec![Json::Num(2.0), Json::Num(4.0)]),
                    Json::Arr(vec![]),
                ]),
            ),
        ]);
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn parses_python_style_output() {
        let v = Json::parse(
            r#"{ "artifacts": [ {"name": "m", "inputs": [[1, 2]], "dtype": "f32" } ],
                 "version": 1 }"#,
        )
        .unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let a = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(a[0].get("name").unwrap().as_str(), Some("m"));
    }

    #[test]
    fn escapes() {
        let s = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let text = s.to_string();
        assert_eq!(Json::parse(&text).unwrap(), s);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        assert!(Json::parse("1.5").unwrap().as_usize().is_none());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
    }
}
