//! In-tree substitutes for common ecosystem crates (this build environment
//! is fully offline; the only external crate is `xla`, and it is optional
//! behind the `pjrt` feature). Everything here is deliberately small and
//! purpose-built:
//!
//! - [`par`]   — scoped thread pool / parallel chunk map (≈ rayon subset)
//! - [`json`]  — minimal JSON writer + parser (manifest + results I/O)
//! - [`bench`] — micro-benchmark timing harness (≈ criterion subset)

pub mod bench;
pub mod json;
pub mod par;
