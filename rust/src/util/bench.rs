//! Tiny micro-benchmark harness (the offline stand-in for criterion).
//!
//! Usage mirrors the paper's protocol (§4.1): a warm-up stage followed by
//! an execution stage; we report the mean plus min/max of the execution
//! stage.

use std::time::Instant;

use crate::util::json::Json;

/// Print and persist a single-line machine-readable benchmark summary —
/// the `BENCH_*.json` files (`BENCH_reduce` / `BENCH_allgather` /
/// `BENCH_hier` / `BENCH_codec`) that track the perf trajectory from PR
/// to PR. Written to the current directory; failure to write is a
/// warning, never an error (the printed line is the canonical record).
pub fn emit_bench_line(file_name: &str, summary: &Json) {
    let line = summary.to_string();
    println!("{file_name} {line}");
    if let Err(e) = std::fs::write(file_name, format!("{line}\n")) {
        eprintln!("warning: could not write {file_name}: {e}");
    }
}

/// Result of one measured benchmark.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Fastest iteration.
    pub min_s: f64,
    /// Slowest iteration.
    pub max_s: f64,
    /// Iterations measured.
    pub iters: usize,
}

impl Measurement {
    /// Throughput in GB/s for `bytes` processed per iteration.
    pub fn gbps(&self, bytes: usize) -> f64 {
        if self.mean_s <= 0.0 {
            return 0.0;
        }
        bytes as f64 / self.mean_s / 1e9
    }
}

/// Run `f` `warmup` + `iters` times, timing only the final `iters`.
pub fn measure<R>(warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> Measurement {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut min_s = f64::INFINITY;
    let mut max_s: f64 = 0.0;
    let mut total = 0.0;
    let iters = iters.max(1);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        min_s = min_s.min(dt);
        max_s = max_s.max(dt);
    }
    Measurement { mean_s: total / iters as f64, min_s, max_s, iters }
}

/// Run `f` repeatedly until `budget_s` seconds elapse (at least once),
/// reporting the mean. Good for auto-scaling iteration counts.
pub fn measure_for<R>(budget_s: f64, mut f: impl FnMut() -> R) -> Measurement {
    // One warmup call.
    std::hint::black_box(f());
    let start = Instant::now();
    let mut iters = 0usize;
    let mut min_s = f64::INFINITY;
    let mut max_s: f64 = 0.0;
    let mut total = 0.0;
    while start.elapsed().as_secs_f64() < budget_s || iters == 0 {
        let t0 = Instant::now();
        std::hint::black_box(f());
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        min_s = min_s.min(dt);
        max_s = max_s.max(dt);
        iters += 1;
        if iters > 1_000_000 {
            break;
        }
    }
    Measurement { mean_s: total / iters as f64, min_s, max_s, iters }
}

/// Render a simple aligned table to stdout (benchmark harness output).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create with column headers.
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }
    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
    }
    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(c);
                for _ in c.len()..widths[i] {
                    out.push(' ');
                }
            }
            out.push('\n');
        };
        line(&self.header, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(row, &widths, &mut out);
        }
        out
    }
    /// Render as CSV (for results/ files).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_reports_sane_numbers() {
        let m = measure(1, 5, || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert_eq!(m.iters, 5);
        assert!(m.min_s <= m.mean_s && m.mean_s <= m.max_s);
        assert!(m.mean_s > 0.0);
    }

    #[test]
    fn gbps() {
        let m = Measurement { mean_s: 0.5, min_s: 0.5, max_s: 0.5, iters: 1 };
        assert!((m.gbps(1_000_000_000) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("long-name"));
        let csv = t.to_csv();
        assert!(csv.starts_with("name,value\n"));
    }
}
