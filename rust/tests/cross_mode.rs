//! Cross-mode equivalence sweep: every collective × every mode × several
//! rank counts and lengths against a serial oracle, with the error
//! envelope appropriate to each mode (single-ê for data movement under
//! ZCCL, depth-scaled for CPRP2P, chain-scaled for computation).

use zccl::collectives::{
    allgather, allreduce, alltoall, bcast, chunk_ranges, gather, reduce, reduce_scatter,
    run_ranks, scatter, Mode, ReduceOp,
};
use zccl::compress::{CompressorKind, ErrorBound};
use zccl::coordinator::Metrics;
use zccl::data::fields::{Field, FieldKind};
use zccl::topology::tree_rounds;

const EB: f64 = 1e-3;

fn modes() -> Vec<(Mode, &'static str)> {
    vec![
        (Mode::plain(), "plain"),
        (Mode::cprp2p(CompressorKind::FzLight, ErrorBound::Abs(EB)), "cprp2p"),
        (Mode::ccoll(ErrorBound::Abs(EB)), "ccoll"),
        (Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(EB)), "zccl"),
        (Mode::zccl(CompressorKind::Szx, ErrorBound::Abs(EB)), "zccl-szx"),
        (
            Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(EB)).with_multithread(true),
            "zccl-mt",
        ),
    ]
}

fn input(rank: usize, len: usize) -> Vec<f32> {
    Field::generate(FieldKind::Hurricane, len, 3000 + rank as u64).values
}

fn assert_close(got: &[f32], want: &[f32], tol: f64, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert!(
            ((a - b).abs() as f64) <= tol,
            "{ctx} idx {i}: |{a} - {b}| > {tol:.2e}"
        );
    }
}

#[test]
fn sweep_allgather() {
    for n in [2usize, 5, 8] {
        for (mode, name) in modes() {
            let len = 700;
            let out = run_ranks(n, move |c| {
                let mut m = Metrics::default();
                allgather(c, &input(c.rank(), len), &mode, &mut m).unwrap()
            });
            let want: Vec<f32> = (0..n).flat_map(|r| input(r, len)).collect();
            // Data movement: zccl/ccoll = ê; cprp2p = (n-1)ê; plain exact.
            let tol = match name {
                "plain" => 1e-7,
                "cprp2p" => (n as f64 - 1.0) * EB * 1.01 + 1e-6,
                _ => EB * 1.01 + 1e-6,
            };
            for o in out {
                assert_close(&o, &want, tol, &format!("allgather {name} n={n}"));
            }
        }
    }
}

#[test]
fn sweep_allreduce_and_reduce_scatter() {
    for n in [2usize, 6] {
        for (mode, name) in modes() {
            let len = 3001;
            let want = {
                let mut acc = input(0, len);
                for r in 1..n {
                    ReduceOp::Sum.fold(&mut acc, &input(r, len));
                }
                acc
            };
            let tol = if name == "plain" { 1e-3 } else { 2.0 * (n as f64 + 1.0) * EB + 1e-3 };
            let out = run_ranks(n, move |c| {
                let mut m = Metrics::default();
                allreduce(c, &input(c.rank(), len), ReduceOp::Sum, &mode, &mut m).unwrap()
            });
            for o in out {
                assert_close(&o, &want, tol, &format!("allreduce {name} n={n}"));
            }
            let out = run_ranks(n, move |c| {
                let mut m = Metrics::default();
                reduce_scatter(c, &input(c.rank(), len), ReduceOp::Sum, &mode, &mut m).unwrap()
            });
            for (range, vals) in out {
                assert_close(
                    &vals,
                    &want[range],
                    tol,
                    &format!("reduce_scatter {name} n={n}"),
                );
            }
        }
    }
}

#[test]
fn sweep_tree_collectives() {
    for n in [2usize, 7, 8] {
        let depth = tree_rounds(n) as f64;
        for (mode, name) in modes() {
            let len = 900;
            let payload = input(99, len);
            // bcast
            let want = payload.clone();
            let p2 = payload.clone();
            let out = run_ranks(n, move |c| {
                let data = (c.rank() == 0).then(|| p2.clone());
                let mut m = Metrics::default();
                bcast(c, data.as_deref(), 0, &mode, &mut m).unwrap()
            });
            let tol = match name {
                "plain" => 1e-7,
                "cprp2p" => depth * EB * 1.01 + 1e-6,
                _ => EB * 1.01 + 1e-6,
            };
            for o in out {
                assert_close(&o, &want, tol, &format!("bcast {name} n={n}"));
            }
            // scatter
            let p3 = payload.clone();
            let out = run_ranks(n, move |c| {
                let data = (c.rank() == 0).then(|| p3.clone());
                let mut m = Metrics::default();
                scatter(c, data.as_deref(), 0, &mode, &mut m).unwrap()
            });
            let ranges = chunk_ranges(len, n);
            for (rank, o) in out.into_iter().enumerate() {
                assert_close(
                    &o,
                    &want[ranges[rank].clone()],
                    tol,
                    &format!("scatter {name} n={n} rank={rank}"),
                );
            }
            // gather
            let out = run_ranks(n, move |c| {
                let mut m = Metrics::default();
                gather(c, &input(c.rank(), 200), 0, &mode, &mut m).unwrap()
            });
            let wantg: Vec<f32> = (0..n).flat_map(|r| input(r, 200)).collect();
            assert_close(
                out[0].as_ref().unwrap(),
                &wantg,
                tol,
                &format!("gather {name} n={n}"),
            );
            // reduce
            let out = run_ranks(n, move |c| {
                let mut m = Metrics::default();
                reduce(c, &input(c.rank(), 500), ReduceOp::Sum, 0, &mode, &mut m).unwrap()
            });
            let mut wantr = input(0, 500);
            for r in 1..n {
                ReduceOp::Sum.fold(&mut wantr, &input(r, 500));
            }
            let rtol = if name == "plain" { 1e-3 } else { 2.0 * (n as f64) * EB + 1e-3 };
            assert_close(
                out[0].as_ref().unwrap(),
                &wantr,
                rtol,
                &format!("reduce {name} n={n}"),
            );
        }
    }
}

#[test]
fn sweep_alltoall() {
    for n in [2usize, 5] {
        for (mode, name) in modes() {
            let len = 1000;
            let out = run_ranks(n, move |c| {
                let mut m = Metrics::default();
                alltoall(c, &input(c.rank(), len), &mode, &mut m).unwrap()
            });
            let ranges = chunk_ranges(len, n);
            let tol = if name == "plain" { 1e-7 } else { EB * 1.01 + 1e-6 };
            for (rank, o) in out.into_iter().enumerate() {
                let want: Vec<f32> = (0..n)
                    .flat_map(|src| input(src, len)[ranges[rank].clone()].to_vec())
                    .collect();
                assert_close(&o, &want, tol, &format!("alltoall {name} n={n} rank={rank}"));
            }
        }
    }
}
