//! Property tests for the nonblocking (`icollective`) API.
//!
//! The contract under test: every wired collective's nonblocking result
//! is **bit-identical** to its blocking twin across rank counts, shapes
//! (including payloads smaller than the communicator) and codecs — the
//! state machines perform the same data operations in the same order as
//! the blocking schedules, only the waiting is rearranged. On top of
//! that: concurrent requests on one context must never cross-match tags,
//! and warm requests must be allocation-free per the pool counters.

use zccl::collectives::{run_ranks, run_ranks_on, CollCtx, Mode, ReduceOp};
use zccl::compress::{CompressorKind, ErrorBound};
use zccl::data::fields::{Field, FieldKind};
use zccl::topology::Topology;

fn rank_field(rank: usize, len: usize, salt: u64) -> Vec<f32> {
    Field::generate(FieldKind::Rtm, len, salt + rank as u64).values
}

fn modes() -> Vec<Mode> {
    let eb = ErrorBound::Abs(1e-3);
    vec![
        Mode::plain(),
        Mode::cprp2p(CompressorKind::FzLight, eb),
        Mode::ccoll(eb),
        Mode::zccl(CompressorKind::FzLight, eb),
        Mode::zccl(CompressorKind::Szx, eb),
    ]
}

fn assert_bits(tag: &str, a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "{tag}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag} idx {i}: {x} vs {y}");
    }
}

#[test]
fn iallreduce_bitwise_matches_blocking() {
    for n in [2usize, 5] {
        // len 3 < n exercises empty ring chunks.
        for len in [3usize, 1000, 4097] {
            for mode in modes() {
                let blocking = run_ranks(n, move |c| {
                    let mut ctx = CollCtx::over(c, mode);
                    let x = rank_field(ctx.rank(), len, 7);
                    ctx.allreduce(&x, ReduceOp::Sum).unwrap()
                });
                let nonblocking = run_ranks(n, move |c| {
                    let mut ctx = CollCtx::over(c, mode);
                    let x = rank_field(ctx.rank(), len, 7);
                    let req = ctx.iallreduce(&x, ReduceOp::Sum).unwrap();
                    ctx.wait(req).unwrap().values
                });
                for (r, (b, nb)) in blocking.iter().zip(&nonblocking).enumerate() {
                    let tag = format!("allreduce {:?} n={n} len={len} rank={r}", mode.algo);
                    assert_bits(&tag, b, nb);
                }
            }
        }
    }
}

#[test]
fn ireduce_scatter_bitwise_matches_blocking() {
    let eb = ErrorBound::Abs(1e-3);
    for n in [3usize, 4] {
        for len in [5usize, 2048] {
            for mode in [
                Mode::plain(),
                Mode::zccl(CompressorKind::FzLight, eb),
                Mode::zccl(CompressorKind::Szx, eb),
            ] {
                let blocking = run_ranks(n, move |c| {
                    let mut ctx = CollCtx::over(c, mode);
                    let x = rank_field(ctx.rank(), len, 31);
                    ctx.reduce_scatter(&x, ReduceOp::Sum).unwrap()
                });
                let nonblocking = run_ranks(n, move |c| {
                    let mut ctx = CollCtx::over(c, mode);
                    let x = rank_field(ctx.rank(), len, 31);
                    let req = ctx.ireduce_scatter(&x, ReduceOp::Sum).unwrap();
                    let out = ctx.wait(req).unwrap();
                    (out.range.expect("reduce-scatter returns a range"), out.values)
                });
                for (r, ((brange, b), (nbrange, nb))) in
                    blocking.iter().zip(&nonblocking).enumerate()
                {
                    let tag = format!("reduce_scatter {:?} n={n} len={len} rank={r}", mode.algo);
                    assert_eq!(brange, nbrange, "{tag}: owned range");
                    assert_bits(&tag, b, nb);
                }
            }
        }
    }
}

#[test]
fn iallgather_bitwise_matches_blocking_uneven_chunks() {
    let eb = ErrorBound::Abs(1e-3);
    for n in [2usize, 5] {
        for mode in [
            Mode::plain(),
            Mode::cprp2p(CompressorKind::FzLight, eb),
            Mode::zccl(CompressorKind::FzLight, eb),
        ] {
            // Every rank contributes a different chunk length.
            let blocking = run_ranks(n, move |c| {
                let mut ctx = CollCtx::over(c, mode);
                let chunk = rank_field(ctx.rank(), 64 + 17 * ctx.rank(), 55);
                ctx.allgather(&chunk).unwrap()
            });
            let nonblocking = run_ranks(n, move |c| {
                let mut ctx = CollCtx::over(c, mode);
                let chunk = rank_field(ctx.rank(), 64 + 17 * ctx.rank(), 55);
                let req = ctx.iallgather(&chunk).unwrap();
                ctx.wait(req).unwrap().values
            });
            for (r, (b, nb)) in blocking.iter().zip(&nonblocking).enumerate() {
                let tag = format!("allgather {:?} n={n} rank={r}", mode.algo);
                assert_bits(&tag, b, nb);
            }
        }
    }
}

#[test]
fn ibcast_bitwise_matches_blocking() {
    let eb = ErrorBound::Abs(1e-3);
    let len = 1000;
    for n in [2usize, 5] {
        let root = n - 1;
        for mode in [
            Mode::plain(),
            Mode::cprp2p(CompressorKind::FzLight, eb),
            Mode::ccoll(eb),
            Mode::zccl(CompressorKind::FzLight, eb),
        ] {
            let blocking = run_ranks(n, move |c| {
                let mut ctx = CollCtx::over(c, mode);
                let payload = (ctx.rank() == root).then(|| rank_field(root, len, 91));
                ctx.bcast(payload.as_deref(), root).unwrap()
            });
            let nonblocking = run_ranks(n, move |c| {
                let mut ctx = CollCtx::over(c, mode);
                let payload = (ctx.rank() == root).then(|| rank_field(root, len, 91));
                let req = ctx.ibcast(payload.as_deref(), root).unwrap();
                ctx.wait(req).unwrap().values
            });
            for (r, (b, nb)) in blocking.iter().zip(&nonblocking).enumerate() {
                let tag = format!("bcast {:?} n={n} rank={r}", mode.algo);
                assert_bits(&tag, b, nb);
            }
        }
    }
}

/// Hier allreduce completes through the blocking fallback at start; the
/// request is done by the first `test()` and bit-identical anyway.
#[test]
fn hier_iallreduce_matches_blocking() {
    let len = 2048;
    let mode = Mode::hier(CompressorKind::FzLight, ErrorBound::Abs(1e-3));
    let topo = Topology::blocked(2, 2);
    let t2 = topo.clone();
    let (blocking, _) = run_ranks_on(&topo, move |c| {
        let mut ctx = CollCtx::over_nodes(c, mode, t2.clone()).unwrap();
        let x = rank_field(ctx.rank(), len, 13);
        ctx.allreduce(&x, ReduceOp::Sum).unwrap()
    });
    let t3 = topo.clone();
    let (nonblocking, _) = run_ranks_on(&topo, move |c| {
        let mut ctx = CollCtx::over_nodes(c, mode, t3.clone()).unwrap();
        let x = rank_field(ctx.rank(), len, 13);
        let req = ctx.iallreduce(&x, ReduceOp::Sum).unwrap();
        assert!(ctx.test(&req).unwrap(), "hier fallback completes eagerly");
        ctx.wait(req).unwrap().values
    });
    for (r, (b, nb)) in blocking.iter().zip(&nonblocking).enumerate() {
        assert_bits(&format!("hier allreduce rank={r}"), b, nb);
    }
}

/// Two in-flight requests on one context: per-request tag-namespace
/// slices mean the ring traffic of the allreduce and the allgather can
/// never cross-match, and completion order is free — here the
/// later-started request is collected first.
#[test]
fn concurrent_requests_complete_out_of_order() {
    let n = 4;
    let len = 2048;
    for mode in [Mode::plain(), Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-3))] {
        let blocking = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let x = rank_field(ctx.rank(), len, 7);
            let g = rank_field(ctx.rank(), len / n, 101);
            (ctx.allreduce(&x, ReduceOp::Sum).unwrap(), ctx.allgather(&g).unwrap())
        });
        let nonblocking = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let x = rank_field(ctx.rank(), len, 7);
            let g = rank_field(ctx.rank(), len / n, 101);
            let r1 = ctx.iallreduce(&x, ReduceOp::Sum).unwrap();
            let r2 = ctx.iallgather(&g).unwrap();
            assert_eq!(ctx.pending_requests(), 2);
            // Reverse completion order: waiting on r2 drives r1 too.
            let ag = ctx.wait(r2).unwrap().values;
            assert_eq!(ctx.pending_requests(), 1);
            let ar = ctx.wait(r1).unwrap().values;
            assert_eq!(ctx.pending_requests(), 0);
            (ar, ag)
        });
        for (r, ((bar, bag), (nar, nag))) in blocking.iter().zip(&nonblocking).enumerate() {
            assert_bits(&format!("concurrent allreduce {:?} rank={r}", mode.algo), bar, nar);
            assert_bits(&format!("concurrent allgather {:?} rank={r}", mode.algo), bag, nag);
        }
    }
}

/// Warm requests are allocation-free: after the pools are primed, more
/// launch/wait_into cycles create no new byte/f32 buffers and lease no
/// new packets — the whole request lifecycle runs on recycled memory.
#[test]
fn warm_requests_are_allocation_free() {
    let n = 4;
    let len = 4096;
    for mode in [Mode::plain(), Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-3))] {
        run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let x = rank_field(ctx.rank(), len, 3);
            let mut out = Vec::new();
            for _ in 0..2 {
                let req = ctx.iallreduce(&x, ReduceOp::Sum).unwrap();
                ctx.wait_into(req, &mut out).unwrap();
            }
            let pool = ctx.pool_stats();
            let packets = ctx.packet_stats();
            for _ in 0..3 {
                let req = ctx.iallreduce(&x, ReduceOp::Sum).unwrap();
                ctx.wait_into(req, &mut out).unwrap();
            }
            let pool2 = ctx.pool_stats();
            let packets2 = ctx.packet_stats();
            let tag = format!("{:?} rank {}", mode.algo, ctx.rank());
            assert_eq!(
                pool.byte_buffers_created, pool2.byte_buffers_created,
                "{tag}: warm requests must not create byte buffers"
            );
            assert_eq!(
                pool.f32_buffers_created, pool2.f32_buffers_created,
                "{tag}: warm requests must not create f32 buffers"
            );
            assert_eq!(
                packets.allocated, packets2.allocated,
                "{tag}: warm requests must not allocate packets"
            );
            assert!(pool2.reuses > pool.reuses, "{tag}: warm requests must reuse the pool");
        });
    }
}

/// Degenerate single-rank requests complete at start (no communication),
/// and invalid `ibcast` arguments fail before anything is parked.
#[test]
fn single_rank_requests_and_invalid_args() {
    run_ranks(1, move |c| {
        let mut ctx = CollCtx::over(c, Mode::plain());
        let x = vec![2.0f32; 17];
        let req = ctx.iallreduce(&x, ReduceOp::Sum).unwrap();
        assert!(ctx.test(&req).unwrap());
        let ar = ctx.wait(req).unwrap().values;
        assert_bits("single-rank allreduce", &ar, &x);
        let req = ctx.ireduce_scatter(&x, ReduceOp::Sum).unwrap();
        let rs = ctx.wait(req).unwrap();
        assert_eq!(rs.range, Some(0..17));
        assert_bits("single-rank reduce_scatter", &rs.values, &x);
        let req = ctx.ibcast(Some(&x), 0).unwrap();
        let bc = ctx.wait(req).unwrap().values;
        assert_bits("single-rank bcast", &bc, &x);
        assert!(ctx.ibcast(Some(&x), 5).is_err(), "out-of-range root must fail");
        assert!(ctx.ibcast(None, 0).is_err(), "root without data must fail");
        assert_eq!(ctx.pending_requests(), 0);
    });
}
