//! Schedule-verifier acceptance suite.
//!
//! 1. The full static sweep ([`verify::verify_all`]) — every collective
//!    × algorithm arm × rank count × topology × root — reports zero
//!    findings, and its JSON verdict says so (the same check `zccl
//!    verify` enforces in CI).
//! 2. The symbolic graphs are not just internally consistent but
//!    *exact*: a traced in-memory fabric run of each collective records
//!    precisely the per-`(src, dst, tag)` message counts
//!    [`graph::message_counts`] predicts — flat arms, hierarchical
//!    topologies (including the `GroupTransport`-translated leader
//!    tier), four concurrently in-flight nonblocking collectives, and
//!    the barrier's generation namespace. Payloads are sized well below
//!    `pipeline_bytes`, so every transfer is a single segment and the
//!    equality is count-for-count.
//! 3. The §3.5.1 pipeline is real on the wire: with `pipeline_bytes`
//!    forced far below the inter-leader bundle size, a traced
//!    hierarchical allgather puts fan > 1 overlapped segments inside a
//!    single ring round's tag window — and not one message lands outside
//!    the graph's declared fan windows.

use zccl::analysis::graph::{self, Coll, Dir, Tags};
use zccl::analysis::verify;
use zccl::collectives::{run_ranks_traced, run_ranks_traced_on, Algo, CollCtx, Mode, ReduceOp};
use zccl::compress::{CompressorKind, ErrorBound};
use zccl::topology::Topology;
use zccl::transport::memchan::MessageLedger;

const EB: f64 = 1e-3;
// Well under pipeline_bytes: every transfer is a single segment.
const LEN: usize = 67;

fn rank_input(rank: usize) -> Vec<f32> {
    (0..LEN).map(|i| ((rank * 131 + i) as f32 * 0.37).sin()).collect()
}

/// Run one blocking collective through the persistent context.
fn run_one(ctx: &mut CollCtx<'_, '_>, coll: Coll, root: usize, x: &[f32], rank: usize) {
    match coll {
        Coll::Barrier => ctx.barrier().unwrap(),
        Coll::Allreduce => {
            ctx.allreduce(x, ReduceOp::Sum).unwrap();
        }
        Coll::ReduceScatter => {
            ctx.reduce_scatter(x, ReduceOp::Sum).unwrap();
        }
        Coll::Allgather => {
            ctx.allgather(x).unwrap();
        }
        Coll::Alltoall => {
            ctx.alltoall(x).unwrap();
        }
        Coll::Bcast => {
            ctx.bcast((rank == root).then_some(x), root).unwrap();
        }
        Coll::Scatter => {
            ctx.scatter((rank == root).then_some(x), root).unwrap();
        }
        Coll::Gather => {
            ctx.gather(x, root).unwrap();
        }
        Coll::Reduce => {
            ctx.reduce(x, ReduceOp::Sum, root).unwrap();
        }
    }
}

/// The graph's predicted ledger for one collective on a fresh
/// communicator.
fn predicted(
    coll: Coll,
    algo: Algo,
    n: usize,
    root: usize,
    topo: Option<&Topology>,
) -> MessageLedger {
    let mut tags = Tags::new();
    graph::message_counts(&[graph::build(coll, algo, n, root, topo, &mut tags)])
}

fn modes() -> Vec<(Algo, Mode)> {
    vec![
        (Algo::Plain, Mode::plain()),
        (Algo::Cprp2p, Mode::cprp2p(CompressorKind::FzLight, ErrorBound::Abs(EB))),
        (Algo::CColl, Mode::ccoll(ErrorBound::Abs(EB))),
        (Algo::Zccl, Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(EB))),
    ]
}

#[test]
fn sweep_is_clean() {
    let report = verify::verify_all();
    for f in &report.findings {
        eprintln!("FINDING {}: [{}] {}", f.case, f.check, f.detail);
    }
    assert!(report.ok(), "{} findings", report.findings.len());
    assert!(report.cases > 500, "swept only {} cases", report.cases);
    assert!(report.messages > 10_000, "counted only {} messages", report.messages);
    assert!(report.to_json().contains("\"ok\":true"));
}

#[test]
fn ledger_matches_graph_flat() {
    for n in [2usize, 3, 5] {
        for (algo, mode) in modes() {
            for coll in Coll::ALL {
                let roots: &[usize] = if coll.rooted() { &[0, n - 1] } else { &[0] };
                for &root in roots {
                    let (_, ledger) = run_ranks_traced(n, move |c| {
                        let rank = c.rank();
                        let x = rank_input(rank);
                        let mut ctx = CollCtx::over(c, mode);
                        run_one(&mut ctx, coll, root, &x, rank);
                    });
                    assert_eq!(
                        ledger,
                        predicted(coll, algo, n, root, None),
                        "{coll:?} {algo:?} n={n} root={root}"
                    );
                }
            }
        }
    }
}

#[test]
fn ledger_matches_graph_hier() {
    let topos = [
        Topology::grouped(&[2, 2]).unwrap(),
        Topology::grouped(&[3, 2]).unwrap(),
        Topology::blocked(2, 3),
    ];
    let mode = Mode::hier(CompressorKind::FzLight, ErrorBound::Abs(EB));
    for topo in topos {
        let n = topo.ranks();
        for coll in Coll::ALL {
            let roots: &[usize] = if coll.rooted() { &[0, n - 1] } else { &[0] };
            for &root in roots {
                let t2 = topo.clone();
                let (_, ledger) = run_ranks_traced_on(&topo, move |c| {
                    let rank = c.rank();
                    let x = rank_input(rank);
                    let mut ctx = CollCtx::over_nodes(c, mode, t2.clone()).unwrap();
                    run_one(&mut ctx, coll, root, &x, rank);
                });
                assert_eq!(
                    ledger,
                    predicted(coll, Algo::Hier, n, root, Some(&topo)),
                    "{coll:?} hier n={n} root={root} nodes={}",
                    topo.nodes()
                );
            }
        }
    }
}

#[test]
fn pipelined_hier_ring_overlaps_segments() {
    // Force the segment size far below the inter-leader bundle size: the
    // slow-tier allgather ring must split each round's bundle into
    // multiple in-flight segments (distinct tags within the round's fan
    // window), while every wire message still lands inside some window
    // the graph declared.
    use std::collections::{BTreeMap, BTreeSet};
    let topo = Topology::grouped(&[2, 2]).unwrap();
    let n = topo.ranks();
    let len = 4096usize;
    let mode =
        Mode::hier(CompressorKind::FzLight, ErrorBound::Abs(EB)).with_pipeline_bytes(1 << 9);
    let t2 = topo.clone();
    let (_, ledger) = run_ranks_traced_on(&topo, move |c| {
        let rank = c.rank();
        let x: Vec<f32> = (0..len).map(|i| ((rank * 131 + i) as f32 * 0.37).sin()).collect();
        let mut ctx = CollCtx::over_nodes(c, mode, t2.clone()).unwrap();
        ctx.allgather(&x).unwrap();
    });
    let mut tags = Tags::new();
    let g = graph::build(Coll::Allgather, Algo::Hier, n, 0, Some(&topo), &mut tags);
    // Map every traced message into the graph send window that covers it.
    let mut per_window: BTreeMap<(usize, usize, u64), BTreeSet<u64>> = BTreeMap::new();
    for &(src, dst, tag) in ledger.keys() {
        let ev = g.scripts[src]
            .iter()
            .find(|ev| {
                ev.dir == Dir::Send && ev.peer == dst && (ev.tag..ev.tag + ev.fan).contains(&tag)
            })
            .unwrap_or_else(|| panic!("message {src}->{dst} tag {tag} outside every fan window"));
        per_window.entry((src, dst, ev.tag)).or_default().insert(tag);
    }
    // Every slow-tier ring round actually went on the wire, and at least
    // one round carried overlapped segments (fan > 1 distinct tags).
    let mut max_segments = 0usize;
    for (src, sc) in g.scripts.iter().enumerate() {
        for ev in sc.iter().filter(|ev| ev.dir == Dir::Send && ev.phase == "hier-ring") {
            let tags_used = per_window
                .get(&(src, ev.peer, ev.tag))
                .unwrap_or_else(|| {
                    panic!("ring round {src}->{} tag {} never sent", ev.peer, ev.tag)
                });
            assert!(
                tags_used.len() as u64 <= ev.fan,
                "{} segment tags overflow fan {}",
                tags_used.len(),
                ev.fan
            );
            max_segments = max_segments.max(tags_used.len());
        }
    }
    assert!(
        max_segments > 1,
        "pipelined ring never split a bundle: at most {max_segments} segment tag(s) per round"
    );
}

#[test]
fn concurrent_icollectives_match_graph() {
    // Four nonblocking collectives in flight at once: the runtime
    // reserves each schedule's tag window at start(), in call order, so
    // the graphs built on one shared counter in the same order must
    // account for every wire message exactly.
    let n = 4;
    let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(EB));
    let (_, ledger) = run_ranks_traced(n, move |c| {
        let rank = c.rank();
        let x = rank_input(rank);
        let mut ctx = CollCtx::over(c, mode);
        let r1 = ctx.iallreduce(&x, ReduceOp::Sum).unwrap();
        let r2 = ctx.ireduce_scatter(&x, ReduceOp::Sum).unwrap();
        let r3 = ctx.iallgather(&x).unwrap();
        let r4 = ctx.ibcast((rank == 0).then_some(&x[..]), 0).unwrap();
        for req in [r1, r2, r3, r4] {
            ctx.wait(req).unwrap();
        }
    });
    let mut tags = Tags::new();
    let ops = [
        graph::build(Coll::Allreduce, Algo::Zccl, n, 0, None, &mut tags),
        graph::build(Coll::ReduceScatter, Algo::Zccl, n, 0, None, &mut tags),
        graph::build(Coll::Allgather, Algo::Zccl, n, 0, None, &mut tags),
        graph::build(Coll::Bcast, Algo::Zccl, n, 0, None, &mut tags),
    ];
    assert_eq!(ledger, graph::message_counts(&ops));
}

#[test]
fn barrier_ledger_matches_graph() {
    for n in [2usize, 3, 5, 8] {
        let (_, ledger) = run_ranks_traced(n, |c| c.barrier().unwrap());
        assert_eq!(ledger, predicted(Coll::Barrier, Algo::Plain, n, 0, None), "n={n}");
    }
}
