//! Placement-decode property suite and receive-path allocation
//! regressions:
//!
//! 1. `decompress_into_slice` must be **bit-identical** to
//!    decompress-then-copy for every codec (all four base codecs, the
//!    multithreaded wrapper, and PIPE), every field kind, and tiny /
//!    empty / chunk-straddling inputs — whether the codec runs a native
//!    in-place kernel or the default.
//! 2. Wrong-sized destinations are rejected before any value lands.
//! 3. A warm iterated ring allgather over memchan performs **zero
//!    byte-buffer allocations and zero post-decode copies** on the
//!    receive path, observable through `PoolStats` (placement vs staged
//!    decode counters, pool creations) and `PacketPoolStats`.

use zccl::collectives::{run_ranks, CollCtx, Mode, PoolStats};
use zccl::compress::{
    build, Compressor, CompressorKind, ErrorBound, MtCompressor, PipeFzLight,
};
use zccl::data::fields::{Field, FieldKind};

/// Sizes crossing every interesting boundary: empty, single value, the
/// 32-value fZ-light block edges, and the 5120-value chunk edges.
const SIZES: [usize; 9] = [0, 1, 31, 32, 33, 5119, 5120, 5121, 12345];

fn codecs() -> Vec<(String, Box<dyn Compressor>)> {
    let mut out: Vec<(String, Box<dyn Compressor>)> = Vec::new();
    for kind in CompressorKind::ALL {
        out.push((format!("{kind:?}"), build(kind)));
        out.push((format!("Mt-{kind:?}"), Box::new(MtCompressor::new(kind))));
    }
    out.push(("PipeFzLight".into(), Box::new(PipeFzLight::default())));
    out
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn placement_decode_is_bit_identical_to_decompress_then_copy() {
    for (name, codec) in codecs() {
        for kind in FieldKind::ALL {
            for &n in &SIZES {
                let data = Field::generate(kind, n, 7).values;
                let frame = codec.compress(&data, ErrorBound::Abs(1e-3)).unwrap();
                let staged = codec.decompress(&frame.bytes).unwrap();
                let mut placed = vec![f32::NAN; n];
                let cnt = codec.decompress_into_slice(&frame.bytes, &mut placed).unwrap();
                assert_eq!(cnt, n, "{name} {kind:?} n={n} count");
                assert_eq!(
                    bits(&placed),
                    bits(&staged),
                    "{name} {kind:?} n={n}: placement decode must be bit-identical"
                );
            }
        }
    }
}

#[test]
fn placement_capability_flags_match_reality() {
    // Native in-place kernels: fZ-light and its wrappers. SZx and ZFP
    // run the decompress-then-copy default and must say so.
    assert!(build(CompressorKind::FzLight).supports_placement_decode());
    assert!(PipeFzLight::default().supports_placement_decode());
    assert!(MtCompressor::new(CompressorKind::FzLight).supports_placement_decode());
    assert!(!build(CompressorKind::Szx).supports_placement_decode());
    assert!(!build(CompressorKind::ZfpAbs).supports_placement_decode());
    assert!(!build(CompressorKind::ZfpFixedRate).supports_placement_decode());
    assert!(!MtCompressor::new(CompressorKind::Szx).supports_placement_decode());
}

#[test]
fn placement_decode_rejects_wrong_destination_length() {
    for (name, codec) in codecs() {
        let data = Field::generate(FieldKind::Cesm, 1000, 9).values;
        let frame = codec.compress(&data, ErrorBound::Abs(1e-3)).unwrap();
        for wrong in [0usize, 999, 1001] {
            let mut dst = vec![0.0f32; wrong];
            assert!(
                codec.decompress_into_slice(&frame.bytes, &mut dst).is_err(),
                "{name}: destination of {wrong} must be rejected for a 1000-value frame"
            );
        }
    }
}

#[test]
fn pipe_placement_decode_runs_progress_hook_per_chunk() {
    let pipe = PipeFzLight::default();
    let data = Field::generate(FieldKind::Rtm, 5120 * 2 + 77, 5).values;
    let frame = pipe.compress(&data, ErrorBound::Abs(1e-3)).unwrap();
    let mut out = vec![0.0f32; data.len()];
    let mut calls = Vec::new();
    let n = pipe
        .decompress_into_slice_with_progress(&frame.bytes, &mut out, &mut |done| calls.push(done))
        .unwrap();
    assert_eq!(n, data.len());
    assert_eq!(calls, vec![5120, 10240, 10317], "§3.5.2 hook must run between chunks");
    assert_eq!(bits(&out), bits(&pipe.decompress(&frame.bytes).unwrap()));
}

/// The tentpole's acceptance regression: a warm ring allgather leases
/// every wire buffer and decodes every frame in place — zero byte-buffer
/// allocations, zero post-decode copies, in both the scratch pool and
/// the transport packet pool.
#[test]
fn warm_ring_allgather_is_allocation_free_and_copy_free() {
    let (n, len) = (4usize, 6000usize);
    let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-3));
    let ok = run_ranks(n, move |c| {
        let mut ctx = CollCtx::over(c, mode);
        let mine = Field::generate(FieldKind::Hurricane, len, ctx.rank() as u64).values;
        let mut out = Vec::new();

        // Deterministically pre-warm the fabric-shared packet pool past
        // any possible concurrent demand (held chunks + in-flight
        // packets), so the post-warm-up allocation counter cannot depend
        // on thread interleaving.
        let warmed: Vec<Vec<u8>> = (0..12)
            .map(|_| {
                let mut b = ctx.transport().lease();
                b.reserve_exact(64 << 10); // non-zero capacity, so release() pools it
                b
            })
            .collect();
        // Holding all leases across a barrier forces the pool to a depth
        // of 12 × n buffers no matter how the rank threads interleave.
        ctx.barrier().unwrap();
        for b in warmed {
            ctx.transport().recycle(b);
        }

        // Two warm-up iterations populate this rank's scratch pool.
        ctx.allgather_into(&mine, &mut out).unwrap();
        ctx.allgather_into(&mine, &mut out).unwrap();
        ctx.barrier().unwrap(); // all ranks quiescent before reading stats
        let warm: PoolStats = ctx.pool_stats();
        let warm_packets = ctx.packet_stats().allocated;
        assert!(warm.byte_buffers_created > 0, "pool must be exercised");
        assert_eq!(warm.staged_decodes, 0, "fZ-light must never stage a decode");
        assert_eq!(
            warm.placement_decodes,
            2 * n as u64,
            "every frame (incl. our own) must placement-decode, each iteration"
        );

        for _ in 0..3 {
            ctx.allgather_into(&mine, &mut out).unwrap();
        }
        ctx.barrier().unwrap();
        let after = ctx.pool_stats();
        assert_eq!(
            after.byte_buffers_created, warm.byte_buffers_created,
            "warm allgather must perform zero byte-buffer allocations"
        );
        assert_eq!(
            after.f32_buffers_created, warm.f32_buffers_created,
            "warm allgather must perform zero f32-buffer allocations"
        );
        assert_eq!(after.staged_decodes, 0, "zero post-decode copies on the receive path");
        assert_eq!(
            after.placement_decodes,
            5 * n as u64,
            "placement decode must keep carrying every frame"
        );
        assert_eq!(
            ctx.packet_stats().allocated,
            warm_packets,
            "warm allgather must lease every wire buffer from the packet pool"
        );
        true
    });
    assert!(ok.into_iter().all(|x| x));
}

/// Codecs without a native placement kernel stay allocation-free through
/// pooled staging — and the stage is counted, not hidden.
#[test]
fn staged_codecs_pool_their_scratch_and_are_counted() {
    let (n, len) = (3usize, 2000usize);
    let mode = Mode::ccoll(ErrorBound::Abs(1e-2)); // SZx: default placement path
    let ok = run_ranks(n, move |c| {
        let mut ctx = CollCtx::over(c, mode);
        let mine = Field::generate(FieldKind::Cesm, len, ctx.rank() as u64).values;
        let mut out = Vec::new();
        ctx.allgather_into(&mine, &mut out).unwrap();
        ctx.allgather_into(&mine, &mut out).unwrap();
        let warm = ctx.pool_stats();
        assert_eq!(warm.placement_decodes, 0, "SZx has no native placement kernel");
        assert_eq!(warm.staged_decodes, 2 * n as u64, "every frame stages through scratch");
        for _ in 0..2 {
            ctx.allgather_into(&mine, &mut out).unwrap();
        }
        let after = ctx.pool_stats();
        assert_eq!(
            after.f32_buffers_created, warm.f32_buffers_created,
            "staging scratch must come from the pool once warm"
        );
        assert_eq!(
            after.byte_buffers_created, warm.byte_buffers_created,
            "staged decode must not allocate byte buffers either"
        );
        true
    });
    assert!(ok.into_iter().all(|x| x));
}

/// End-to-end cross-check: the placement-decode receive path yields the
/// same collective results as the seed's staged path did — every rank
/// identical, error bounded, for every movement collective.
#[test]
fn movement_collectives_stay_bounded_under_placement_decode() {
    let (n, len) = (4usize, 3000usize);
    let eb = 1e-3f64;
    for kind in [CompressorKind::FzLight, CompressorKind::Szx] {
        let mode = Mode::zccl(kind, ErrorBound::Abs(eb));
        let out = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let mine = Field::generate(FieldKind::Nyx, len, 70 + ctx.rank() as u64).values;
            let gathered = ctx.allgather(&mine).unwrap();
            let root_data = (ctx.rank() == 0)
                .then(|| Field::generate(FieldKind::Nyx, len, 7).values);
            let bcasted = ctx.bcast(root_data.as_deref(), 0).unwrap();
            let scattered = ctx.scatter(root_data.as_deref(), 0).unwrap();
            let exchanged = ctx.alltoall(&mine).unwrap();
            (gathered, bcasted, scattered, exchanged)
        });
        let want_gather: Vec<f32> = (0..n)
            .flat_map(|r| Field::generate(FieldKind::Nyx, len, 70 + r as u64).values)
            .collect();
        let want_root = Field::generate(FieldKind::Nyx, len, 7).values;
        let ranges = zccl::collectives::chunk_ranges(len, n);
        for (rank, (g, b, s, x)) in out.iter().enumerate() {
            assert_eq!(g.len(), want_gather.len(), "{kind:?}");
            for (a, w) in g.iter().zip(&want_gather) {
                assert!((a - w).abs() as f64 <= eb * 1.001 + 1e-6, "{kind:?} allgather");
            }
            for (a, w) in b.iter().zip(&want_root) {
                assert!((a - w).abs() as f64 <= eb * 1.001 + 1e-6, "{kind:?} bcast");
            }
            for (a, w) in s.iter().zip(&want_root[ranges[rank].clone()]) {
                assert!((a - w).abs() as f64 <= eb * 1.001 + 1e-6, "{kind:?} scatter");
            }
            assert_eq!(x.len(), len, "{kind:?} alltoall length");
        }
        // MPI semantics: allgather/bcast identical on every rank.
        for (g, b, _, _) in &out[1..] {
            assert_eq!(g, &out[0].0, "{kind:?}");
            assert_eq!(b, &out[0].1, "{kind:?}");
        }
    }
}
