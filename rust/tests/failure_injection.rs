//! Failure injection and robustness: corrupt/truncated frames must fail
//! with errors (never panic, never return wrong-length data), the codecs
//! must round-trip adversarial inputs, and adversarial *timing* (delayed
//! senders, straggler ranks) must leave the nonblocking collectives
//! bit-identical to their blocking twins.

use zccl::compress::{self, Compressor, CompressorKind, ErrorBound};
use zccl::data::rng::Rng;

/// Deterministic fuzz: random values at extreme magnitudes, with NaN-free
/// adversarial patterns, across every codec.
#[test]
fn codec_fuzz_roundtrip_bounds() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..40 {
        let n = 1 + rng.below(9000);
        let scale = 10f64.powi(rng.below(9) as i32 - 4); // 1e-4 ..= 1e4
        let data: Vec<f32> = (0..n)
            .map(|i| {
                let base = match case % 4 {
                    0 => rng.normal(),
                    1 => (i as f64 * 0.01).sin(),
                    2 => (i % 7) as f64, // step pattern
                    _ => rng.uniform() - 0.5,
                };
                (base * scale) as f32
            })
            .collect();
        for kind in [CompressorKind::FzLight, CompressorKind::Szx, CompressorKind::ZfpAbs] {
            let eb_rel = [1e-2, 1e-4][case % 2];
            let eb = ErrorBound::Rel(eb_rel);
            let eb_abs = eb.resolve(&data);
            let codec = compress::build(kind);
            let frame = codec.compress(&data, eb).unwrap();
            let back = codec.decompress(&frame.bytes).unwrap();
            assert_eq!(back.len(), data.len(), "{kind:?} case {case}");
            for (i, (a, b)) in data.iter().zip(&back).enumerate() {
                let err = (*a as f64 - *b as f64).abs();
                let tol = eb_abs * (1.0 + 1e-5) + a.abs() as f64 * 1e-6 + 1e-30;
                assert!(err <= tol, "{kind:?} case {case} idx {i}: {err:.3e} > {tol:.3e}");
            }
        }
    }
}

/// Bit-flip fuzz: flipping any byte of a frame must produce Err or a
/// decodable (possibly wrong) value — never a panic or an OOM-sized
/// allocation.
#[test]
fn bitflip_never_panics() {
    let data: Vec<f32> = (0..3000).map(|i| (i as f32 * 0.01).cos()).collect();
    for kind in [CompressorKind::FzLight, CompressorKind::Szx] {
        let codec = compress::build(kind);
        let frame = codec.compress(&data, ErrorBound::Abs(1e-3)).unwrap();
        let mut rng = Rng::new(kind.id() as u64);
        for _ in 0..200 {
            let mut corrupted = frame.bytes.clone();
            let pos = rng.below(corrupted.len());
            corrupted[pos] ^= 1 << rng.below(8);
            // Result is allowed to be Ok (payload-bit flips change values)
            // but must never panic and never produce the wrong element
            // count on Ok.
            if let Ok(out) = codec.decompress(&corrupted) {
                assert_eq!(out.len(), data.len());
            }
        }
    }
}

/// Every truncation point of a frame must yield Err (not panic).
#[test]
fn truncation_always_err() {
    let data: Vec<f32> = (0..2000).map(|i| (i as f32).sqrt()).collect();
    for kind in CompressorKind::ALL {
        let codec = compress::build(kind);
        let frame = codec.compress(&data, ErrorBound::Rel(1e-3)).unwrap();
        // Exhaustive near the header, sampled through the body.
        let mut cuts: Vec<usize> = (0..64.min(frame.bytes.len())).collect();
        let mut c = 64;
        while c < frame.bytes.len() {
            cuts.push(c);
            c += 97;
        }
        for cut in cuts {
            assert!(
                codec.decompress(&frame.bytes[..cut]).is_err(),
                "{kind:?}: truncation at {cut} must fail"
            );
        }
    }
}

/// Cross-codec confusion: an SZx frame handed to the generic decoder
/// dispatches correctly; a frame with a forged codec id fails cleanly.
#[test]
fn codec_dispatch_and_forgery() {
    let data = vec![1.0f32; 500];
    let frame = compress::build(CompressorKind::Szx)
        .compress(&data, ErrorBound::Abs(1e-3))
        .unwrap();
    // Generic dispatch works.
    assert_eq!(compress::decompress(&frame.bytes).unwrap().len(), 500);
    // Forged codec id: either a clean parse error or a wrong-type error —
    // decompressing szx bytes as fzlight must not panic.
    let mut forged = frame.bytes.clone();
    forged[5] = CompressorKind::FzLight.id();
    let _ = compress::decompress(&forged); // must not panic
    // Unknown codec id errors.
    forged[5] = 0x7F;
    assert!(compress::decompress(&forged).is_err());
}

/// Deterministic per-rank input for the nonblocking timing tests.
fn rank_input(rank: usize) -> Vec<f32> {
    (0..5000).map(|i| ((i + rank * 1013) as f32 * 0.001).sin()).collect()
}

/// Delayed sender: one rank sleeps before even *starting* its request,
/// so every other rank's receives find nothing and their state machines
/// must yield (not block) across many `test()` polls. Once the sleeper
/// joins, the result must be bit-identical to the blocking collective on
/// the same inputs — timing can rearrange waiting, never data.
#[test]
fn nonblocking_delayed_sender_matches_blocking_bitwise() {
    use zccl::collectives::{run_ranks, CollCtx, Mode, ReduceOp};
    let n = 4;
    for mode in [Mode::plain(), Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-3))] {
        let blocking = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let x = rank_input(ctx.rank());
            ctx.allreduce(&x, ReduceOp::Sum).unwrap()
        });
        let nonblocking = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let x = rank_input(ctx.rank());
            if ctx.rank() == 1 {
                std::thread::sleep(std::time::Duration::from_millis(30));
            }
            let req = ctx.iallreduce(&x, ReduceOp::Sum).unwrap();
            while !ctx.test(&req).unwrap() {
                std::thread::yield_now();
            }
            ctx.wait(req).unwrap().values
        });
        for (rank, (b, nb)) in blocking.iter().zip(&nonblocking).enumerate() {
            assert_eq!(b.len(), nb.len());
            for (i, (x, y)) in b.iter().zip(nb).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "mode {:?} rank {rank} idx {i}: {x} vs {y}",
                    mode.algo
                );
            }
        }
    }
}

/// Straggler rank: one rank drives progress only every few milliseconds
/// while the others poll hot. The ring stalls on the straggler each
/// round (its sends and folds gate its neighbours), but completion and
/// bit-identity with the blocking schedule must be unaffected.
#[test]
fn nonblocking_straggler_rank_matches_blocking_bitwise() {
    use zccl::collectives::{run_ranks, CollCtx, Mode, ReduceOp};
    let n = 4;
    for mode in [Mode::plain(), Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-3))] {
        let blocking = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let x = rank_input(ctx.rank());
            ctx.allreduce(&x, ReduceOp::Sum).unwrap()
        });
        let nonblocking = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let x = rank_input(ctx.rank());
            let req = ctx.iallreduce(&x, ReduceOp::Sum).unwrap();
            let lazy = ctx.rank() == 2;
            while !ctx.test(&req).unwrap() {
                if lazy {
                    std::thread::sleep(std::time::Duration::from_millis(3));
                } else {
                    std::thread::yield_now();
                }
            }
            ctx.wait(req).unwrap().values
        });
        for (rank, (b, nb)) in blocking.iter().zip(&nonblocking).enumerate() {
            assert_eq!(b.len(), nb.len());
            for (i, (x, y)) in b.iter().zip(nb).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "mode {:?} rank {rank} idx {i}: {x} vs {y}",
                    mode.algo
                );
            }
        }
    }
}

/// Sending a frame through a collective where one rank's data is
/// pathological (all NaN-free extremes) keeps every rank's output length
/// correct under all modes.
#[test]
fn extreme_values_through_allreduce() {
    use zccl::collectives::{allreduce, run_ranks, Mode, ReduceOp};
    use zccl::coordinator::Metrics;
    let n = 4;
    let len = 4096;
    for mode in [
        Mode::plain(),
        Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-2)),
        Mode::cprp2p(CompressorKind::Szx, ErrorBound::Abs(1e-2)),
    ] {
        let out = run_ranks(n, move |c| {
            // Rank 2 contributes huge-magnitude alternating data.
            let input: Vec<f32> = if c.rank() == 2 {
                (0..len).map(|i| if i % 2 == 0 { 1e6 } else { -1e6 }).collect()
            } else {
                (0..len).map(|i| (i as f32 * 0.001).sin()).collect()
            };
            let mut m = Metrics::default();
            allreduce(c, &input, ReduceOp::Sum, &mode, &mut m).unwrap()
        });
        for o in &out {
            assert_eq!(o.len(), len);
            assert!(o.iter().all(|v| v.is_finite()));
        }
        for o in &out[1..] {
            // All ranks agree bit-for-bit within each mode (identical
            // fold order and identical frames).
            assert_eq!(o.len(), out[0].len());
        }
    }
}
