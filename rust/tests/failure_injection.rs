//! Failure injection and robustness: corrupt/truncated frames must fail
//! with errors (never panic, never return wrong-length data), the codecs
//! must round-trip adversarial inputs, and adversarial *timing* (delayed
//! senders, straggler ranks) must leave the nonblocking collectives
//! bit-identical to their blocking twins.

use zccl::compress::fzlight::STAGE_ENTROPY;
use zccl::compress::{self, Compressor, CompressorKind, ErrorBound, FzLight};
use zccl::data::rng::Rng;

/// Deterministic fuzz: random values at extreme magnitudes, with NaN-free
/// adversarial patterns, across every codec.
#[test]
fn codec_fuzz_roundtrip_bounds() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..40 {
        let n = 1 + rng.below(9000);
        let scale = 10f64.powi(rng.below(9) as i32 - 4); // 1e-4 ..= 1e4
        let data: Vec<f32> = (0..n)
            .map(|i| {
                let base = match case % 4 {
                    0 => rng.normal(),
                    1 => (i as f64 * 0.01).sin(),
                    2 => (i % 7) as f64, // step pattern
                    _ => rng.uniform() - 0.5,
                };
                (base * scale) as f32
            })
            .collect();
        for kind in [CompressorKind::FzLight, CompressorKind::Szx, CompressorKind::ZfpAbs] {
            let eb_rel = [1e-2, 1e-4][case % 2];
            let eb = ErrorBound::Rel(eb_rel);
            let eb_abs = eb.resolve(&data);
            let codec = compress::build(kind);
            let frame = codec.compress(&data, eb).unwrap();
            let back = codec.decompress(&frame.bytes).unwrap();
            assert_eq!(back.len(), data.len(), "{kind:?} case {case}");
            for (i, (a, b)) in data.iter().zip(&back).enumerate() {
                let err = (*a as f64 - *b as f64).abs();
                let tol = eb_abs * (1.0 + 1e-5) + a.abs() as f64 * 1e-6 + 1e-30;
                assert!(err <= tol, "{kind:?} case {case} idx {i}: {err:.3e} > {tol:.3e}");
            }
        }
    }
}

/// Bit-flip fuzz: flipping any byte of a frame must produce Err or a
/// decodable (possibly wrong) value — never a panic or an OOM-sized
/// allocation.
#[test]
fn bitflip_never_panics() {
    let data: Vec<f32> = (0..3000).map(|i| (i as f32 * 0.01).cos()).collect();
    for kind in [CompressorKind::FzLight, CompressorKind::Szx] {
        let codec = compress::build(kind);
        let frame = codec.compress(&data, ErrorBound::Abs(1e-3)).unwrap();
        let mut rng = Rng::new(kind.id() as u64);
        for _ in 0..200 {
            let mut corrupted = frame.bytes.clone();
            let pos = rng.below(corrupted.len());
            corrupted[pos] ^= 1 << rng.below(8);
            // Result is allowed to be Ok (payload-bit flips change values)
            // but must never panic and never produce the wrong element
            // count on Ok.
            if let Ok(out) = codec.decompress(&corrupted) {
                assert_eq!(out.len(), data.len());
            }
        }
    }
}

/// Every truncation point of a frame must yield Err (not panic).
#[test]
fn truncation_always_err() {
    let data: Vec<f32> = (0..2000).map(|i| (i as f32).sqrt()).collect();
    for kind in CompressorKind::ALL {
        let codec = compress::build(kind);
        let frame = codec.compress(&data, ErrorBound::Rel(1e-3)).unwrap();
        // Exhaustive near the header, sampled through the body.
        let mut cuts: Vec<usize> = (0..64.min(frame.bytes.len())).collect();
        let mut c = 64;
        while c < frame.bytes.len() {
            cuts.push(c);
            c += 97;
        }
        for cut in cuts {
            assert!(
                codec.decompress(&frame.bytes[..cut]).is_err(),
                "{kind:?}: truncation at {cut} must fail"
            );
        }
    }
}

/// Cross-codec confusion: an SZx frame handed to the generic decoder
/// dispatches correctly; a frame with a forged codec id fails cleanly.
#[test]
fn codec_dispatch_and_forgery() {
    let data = vec![1.0f32; 500];
    let frame = compress::build(CompressorKind::Szx)
        .compress(&data, ErrorBound::Abs(1e-3))
        .unwrap();
    // Generic dispatch works.
    assert_eq!(compress::decompress(&frame.bytes).unwrap().len(), 500);
    // Forged codec id: either a clean parse error or a wrong-type error —
    // decompressing szx bytes as fzlight must not panic.
    let mut forged = frame.bytes.clone();
    forged[5] = CompressorKind::FzLight.id();
    let _ = compress::decompress(&forged); // must not panic
    // Unknown codec id errors.
    forged[5] = 0x7F;
    assert!(compress::decompress(&forged).is_err());
}

/// Staged (version-2) frames under the same adversarial treatment:
/// single-bit flips — exhaustive across the first entropy-coded chunk
/// payload (stage tag, `raw_len` word, rANS blob), sampled everywhere
/// else — must yield a typed `Corrupt` error or a right-length decode,
/// never a panic; truncation at every cut must fail; and a forged
/// entropy `raw_len` must be rejected by the sizing guard before any
/// scratch is allocated from it.
#[test]
fn staged_bitflip_and_truncation_never_panic() {
    let data: Vec<f32> = (0..3000).map(|i| (i / 500) as f32).collect();
    let codec = FzLight::with_chunk(512).with_staged(true);
    let frame = codec.compress(&data, ErrorBound::Abs(1e-3)).unwrap();
    assert_eq!(frame.bytes[4], 2, "staged frames carry version 2");
    assert!(frame.stats.entropy_chunks > 0, "plateau chunks must entropy-code");
    // Frame geometry: 24-byte header + chunk_values + nchunks + sizes.
    let nchunks = u32::from_le_bytes(frame.bytes[28..32].try_into().unwrap()) as usize;
    assert_eq!(nchunks, 6);
    let size0 = u32::from_le_bytes(frame.bytes[32..36].try_into().unwrap()) as usize;
    let first = 32 + 4 * nchunks;
    assert_eq!(frame.bytes[first], STAGE_ENTROPY, "first chunk must be entropy-coded");
    for pos in first..first + size0 {
        for bit in 0..8 {
            let mut corrupted = frame.bytes.clone();
            corrupted[pos] ^= 1 << bit;
            match codec.decompress(&corrupted) {
                Ok(out) => assert_eq!(out.len(), data.len(), "flip {pos}:{bit}"),
                Err(e) => assert!(
                    matches!(e, Error::Corrupt(_)),
                    "flip {pos}:{bit}: want typed Corrupt, got {e:?}"
                ),
            }
        }
    }
    // Sampled flips over the rest of the frame (header, chunk table,
    // fixed-width neighbours).
    let mut rng = Rng::new(0x57A6ED2);
    for _ in 0..300 {
        let mut corrupted = frame.bytes.clone();
        let pos = rng.below(corrupted.len());
        corrupted[pos] ^= 1 << rng.below(8);
        if let Ok(out) = codec.decompress(&corrupted) {
            assert_eq!(out.len(), data.len());
        }
    }
    // Every truncation point fails cleanly.
    for cut in 0..frame.bytes.len() {
        assert!(codec.decompress(&frame.bytes[..cut]).is_err(), "staged cut {cut}");
    }
    // Forged raw_len: an entropy chunk claiming a u32::MAX payload must
    // die on the per-chunk bound, not size a buffer from the claim.
    let mut forged = frame.bytes.clone();
    forged[first + 1..first + 5].copy_from_slice(&u32::MAX.to_le_bytes());
    let e = codec.decompress(&forged).expect_err("forged raw_len must fail");
    assert!(matches!(e, Error::Corrupt(_)), "typed error: {e:?}");
}

/// Deterministic per-rank input for the nonblocking timing tests.
fn rank_input(rank: usize) -> Vec<f32> {
    (0..5000).map(|i| ((i + rank * 1013) as f32 * 0.001).sin()).collect()
}

/// Delayed sender: one rank sleeps before even *starting* its request,
/// so every other rank's receives find nothing and their state machines
/// must yield (not block) across many `test()` polls. Once the sleeper
/// joins, the result must be bit-identical to the blocking collective on
/// the same inputs — timing can rearrange waiting, never data.
#[test]
fn nonblocking_delayed_sender_matches_blocking_bitwise() {
    use zccl::collectives::{run_ranks, CollCtx, Mode, ReduceOp};
    let n = 4;
    for mode in [Mode::plain(), Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-3))] {
        let blocking = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let x = rank_input(ctx.rank());
            ctx.allreduce(&x, ReduceOp::Sum).unwrap()
        });
        let nonblocking = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let x = rank_input(ctx.rank());
            if ctx.rank() == 1 {
                std::thread::sleep(std::time::Duration::from_millis(30));
            }
            let req = ctx.iallreduce(&x, ReduceOp::Sum).unwrap();
            while !ctx.test(&req).unwrap() {
                std::thread::yield_now();
            }
            ctx.wait(req).unwrap().values
        });
        for (rank, (b, nb)) in blocking.iter().zip(&nonblocking).enumerate() {
            assert_eq!(b.len(), nb.len());
            for (i, (x, y)) in b.iter().zip(nb).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "mode {:?} rank {rank} idx {i}: {x} vs {y}",
                    mode.algo
                );
            }
        }
    }
}

/// Straggler rank: one rank drives progress only every few milliseconds
/// while the others poll hot. The ring stalls on the straggler each
/// round (its sends and folds gate its neighbours), but completion and
/// bit-identity with the blocking schedule must be unaffected.
#[test]
fn nonblocking_straggler_rank_matches_blocking_bitwise() {
    use zccl::collectives::{run_ranks, CollCtx, Mode, ReduceOp};
    let n = 4;
    for mode in [Mode::plain(), Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-3))] {
        let blocking = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let x = rank_input(ctx.rank());
            ctx.allreduce(&x, ReduceOp::Sum).unwrap()
        });
        let nonblocking = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let x = rank_input(ctx.rank());
            let req = ctx.iallreduce(&x, ReduceOp::Sum).unwrap();
            let lazy = ctx.rank() == 2;
            while !ctx.test(&req).unwrap() {
                if lazy {
                    std::thread::sleep(std::time::Duration::from_millis(3));
                } else {
                    std::thread::yield_now();
                }
            }
            ctx.wait(req).unwrap().values
        });
        for (rank, (b, nb)) in blocking.iter().zip(&nonblocking).enumerate() {
            assert_eq!(b.len(), nb.len());
            for (i, (x, y)) in b.iter().zip(nb).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "mode {:?} rank {rank} idx {i}: {x} vs {y}",
                    mode.algo
                );
            }
        }
    }
}

/// Sending a frame through a collective where one rank's data is
/// pathological (all NaN-free extremes) keeps every rank's output length
/// correct under all modes.
#[test]
fn extreme_values_through_allreduce() {
    use zccl::collectives::{allreduce, run_ranks, Mode, ReduceOp};
    use zccl::coordinator::Metrics;
    let n = 4;
    let len = 4096;
    for mode in [
        Mode::plain(),
        Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-2)),
        Mode::cprp2p(CompressorKind::Szx, ErrorBound::Abs(1e-2)),
    ] {
        let out = run_ranks(n, move |c| {
            // Rank 2 contributes huge-magnitude alternating data.
            let input: Vec<f32> = if c.rank() == 2 {
                (0..len).map(|i| if i % 2 == 0 { 1e6 } else { -1e6 }).collect()
            } else {
                (0..len).map(|i| (i as f32 * 0.001).sin()).collect()
            };
            let mut m = Metrics::default();
            allreduce(c, &input, ReduceOp::Sum, &mode, &mut m).unwrap()
        });
        for o in &out {
            assert_eq!(o.len(), len);
            assert!(o.iter().all(|v| v.is_finite()));
        }
        for o in &out[1..] {
            // All ranks agree bit-for-bit within each mode (identical
            // fold order and identical frames).
            assert_eq!(o.len(), out[0].len());
        }
    }
}

// ---------------------------------------------------------------------
// Chaos suite: deterministic fault injection against live collectives.
//
// Every test below wraps each rank's in-process endpoint in a
// [`FaultTransport`] driven by a seeded [`FaultPlan`] and runs a real
// collective across 4 ranks. The contract under chaos is binary: a rank
// either returns the bit-exact result of the equivalent clean run, or a
// clean typed error (`Timeout` / `Transport` / `Corrupt`) within its
// deadline. Panics and hangs are failures. Seeds come from
// `ZCCL_CHAOS_SEED` (CI sweeps a fixed 3-seed matrix) with a fixed
// default, so every run is reproducible.
// ---------------------------------------------------------------------

use std::time::{Duration, Instant};

use zccl::collectives::{CollCtx, Communicator, Mode, ReduceOp};
use zccl::coordinator::Metrics;
use zccl::transport::fault::{FaultPlan, FaultTransport};
use zccl::transport::memchan::MemFabric;
use zccl::Error;

const CHAOS_RANKS: usize = 4;
/// The rank whose transport misbehaves in every chaos scenario.
const FAULTY: usize = 1;

/// Seed for the fault plans: `ZCCL_CHAOS_SEED` if set (the CI matrix
/// sweeps 1..=3), else a fixed default.
fn chaos_seed() -> u64 {
    std::env::var("ZCCL_CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xC0FFEE)
}

fn chaos_mode(kind: CompressorKind) -> Mode {
    Mode::zccl(kind, ErrorBound::Abs(1e-3))
}

fn chaos_input(rank: usize) -> Vec<f32> {
    (0..3000).map(|i| ((i + rank * 977) as f32 * 0.002).sin()).collect()
}

/// Per-rank fault plans: `faulty` gets `plan`, everyone else runs clean.
fn plans_for(n: usize, faulty: usize, plan: FaultPlan) -> Vec<FaultPlan> {
    (0..n)
        .map(|r| if r == faulty { plan.clone() } else { FaultPlan::new(chaos_seed() ^ r as u64) })
        .collect()
}

/// Spawn one thread per rank over a fresh in-process fabric, each rank's
/// endpoint wrapped in a [`FaultTransport`] running its plan. Panics in
/// any rank fail the test; typed errors are returned for inspection.
fn run_chaos<R, F>(plans: Vec<FaultPlan>, f: F) -> Vec<R>
where
    R: Send + 'static,
    F: Fn(&mut Communicator) -> R + Send + Sync + 'static,
{
    let f = std::sync::Arc::new(f);
    let handles: Vec<_> = MemFabric::endpoints(plans.len())
        .into_iter()
        .zip(plans)
        .map(|(t, plan)| {
            let f = std::sync::Arc::clone(&f);
            std::thread::spawn(move || {
                let mut ft = FaultTransport::new(t, plan);
                let mut comm = Communicator::new(&mut ft);
                f(&mut comm)
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().expect("chaos rank must not panic")).collect()
}

/// The collective under test, selected by index so the matrix can loop.
fn chaos_op(ctx: &mut CollCtx, op: usize) -> Result<Vec<f32>, Error> {
    let rank = ctx.rank();
    let x = chaos_input(rank);
    match op {
        0 => ctx.allreduce(&x, ReduceOp::Sum),
        1 => ctx.reduce_scatter(&x, ReduceOp::Sum).map(|(_, v)| v),
        2 => ctx.allgather(&x[..200 + 13 * rank]),
        _ => ctx.bcast((rank == 0).then_some(x.as_slice()), 0),
    }
}

/// Drive the {collective} × {codec} matrix under one fault plan. Benign
/// plans (duplicate, delay) must be fully transparent: every rank Ok and
/// bit-exact against the clean run. Harmful plans (drop, corrupt, dead
/// peer) must fail *cleanly*: at least one rank errors, every error is a
/// typed `Timeout`/`Transport`/`Corrupt`, and any rank that does finish
/// must still produce the bit-exact clean result — faults may stall or
/// kill a collective but never silently corrupt its output.
fn chaos_matrix(make_plan: impl Fn(u64) -> FaultPlan, harmful: bool) {
    for kind in [CompressorKind::FzLight, CompressorKind::Szx] {
        for op in 0..4usize {
            let mode = chaos_mode(kind);
            let clean = run_chaos(
                plans_for(CHAOS_RANKS, FAULTY, FaultPlan::new(chaos_seed())),
                move |c| {
                    let mut ctx = CollCtx::over(c, mode);
                    chaos_op(&mut ctx, op).expect("clean run must succeed")
                },
            );
            let deadline = if harmful { 300 } else { 5000 };
            let t0 = Instant::now();
            let chaotic = run_chaos(
                plans_for(CHAOS_RANKS, FAULTY, make_plan(chaos_seed())),
                move |c| {
                    let mut ctx = CollCtx::over(c, mode);
                    ctx.set_timeout(Some(Duration::from_millis(deadline)));
                    chaos_op(&mut ctx, op)
                },
            );
            assert!(
                t0.elapsed() < Duration::from_secs(20),
                "op {op} under {kind:?}: chaos run must resolve promptly"
            );
            let mut errs = 0;
            for (rank, r) in chaotic.iter().enumerate() {
                match r {
                    Ok(v) => assert_eq!(
                        v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                        clean[rank].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                        "op {op} under {kind:?}: rank {rank} finished with wrong bits"
                    ),
                    Err(e) => {
                        errs += 1;
                        assert!(
                            matches!(
                                e,
                                Error::Timeout { .. } | Error::Transport(_) | Error::Corrupt(_)
                            ),
                            "op {op} under {kind:?}: rank {rank} got untyped error {e:?}"
                        );
                    }
                }
            }
            if harmful {
                assert!(errs > 0, "op {op} under {kind:?}: harmful plan must surface");
            } else {
                assert_eq!(errs, 0, "op {op} under {kind:?}: benign plan must be transparent");
            }
        }
    }
}

#[test]
fn chaos_duplicated_frames_are_transparent() {
    chaos_matrix(|s| FaultPlan::new(s).duplicate_frames(1.0), false);
}

#[test]
fn chaos_delayed_frames_are_transparent() {
    chaos_matrix(|s| FaultPlan::new(s).delay_frames(1.0, Duration::from_millis(1)), false);
}

#[test]
fn chaos_dropped_frames_fail_cleanly() {
    chaos_matrix(|s| FaultPlan::new(s).drop_frames(1.0), true);
}

#[test]
fn chaos_corrupt_frames_fail_cleanly() {
    chaos_matrix(|s| FaultPlan::new(s).corrupt_frames(1.0), true);
}

#[test]
fn chaos_dead_peer_fails_cleanly() {
    chaos_matrix(|s| FaultPlan::new(s).kill_after(0), true);
}

/// Acceptance: a 4-rank ZCCL allreduce with one rank killed
/// mid-collective (after its first two ring sends) returns a typed
/// `Timeout` or `Transport` error on **every** surviving rank within the
/// armed deadline, the killed rank reports its own death, at least one
/// survivor's timeout names the dead peer in its pending-receive list,
/// and the timeout lands in that survivor's `Metrics`.
#[test]
fn chaos_dead_rank_mid_allreduce_fails_survivors_within_deadline() {
    let plan = FaultPlan::new(chaos_seed()).kill_after(2);
    let t0 = Instant::now();
    let results: Vec<(Result<Vec<f32>, Error>, Metrics)> =
        run_chaos(plans_for(CHAOS_RANKS, FAULTY, plan), move |c| {
            let mut ctx = CollCtx::over(c, chaos_mode(CompressorKind::FzLight));
            ctx.set_timeout(Some(Duration::from_millis(400)));
            let r = chaos_op(&mut ctx, 0);
            (r, *ctx.metrics())
        });
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "survivors must detect the dead rank promptly"
    );
    for (rank, (r, _)) in results.iter().enumerate() {
        let e = r.as_ref().expect_err("no rank can finish the ring with rank 1 dead");
        if rank == FAULTY {
            assert!(
                format!("{e}").contains("killed by fault plan"),
                "dead rank reports its own death: {e}"
            );
        } else {
            assert!(
                matches!(e, Error::Timeout { .. } | Error::Transport(_)),
                "rank {rank}: want Timeout or Transport, got {e:?}"
            );
        }
    }
    // The first survivor to starve is the dead rank's ring successor: its
    // deadline expires on a receive posted against rank 1, the timeout
    // names that pending (peer, tag), and Metrics counts it.
    let starved = results.iter().enumerate().any(|(rank, (r, m))| {
        rank != FAULTY
            && m.timeouts > 0
            && matches!(r, Err(Error::Timeout { pending })
                if pending.iter().any(|&(peer, _)| peer == FAULTY))
    });
    assert!(starved, "some survivor must time out naming the dead peer as pending");
}

/// Acceptance: a single bit flipped in a compressed frame is caught by
/// the CRC at delivery — before the codec ever parses the payload — and
/// the error names the sending rank. The receiver's `Metrics` counts the
/// corrupt frame.
#[test]
fn chaos_corruption_is_detected_before_decode_naming_sender() {
    let plan = FaultPlan::new(chaos_seed()).corrupt_frames(1.0);
    let results: Vec<(Result<Vec<f32>, Error>, Metrics)> =
        run_chaos(plans_for(CHAOS_RANKS, FAULTY, plan), move |c| {
            let mut ctx = CollCtx::over(c, chaos_mode(CompressorKind::FzLight));
            ctx.set_timeout(Some(Duration::from_millis(400)));
            let r = chaos_op(&mut ctx, 0);
            (r, *ctx.metrics())
        });
    // Rank 2 sits directly after the faulty rank on the ring, so its
    // first receive of rank 1's compressed frame fails verification. Had
    // the bytes reached the codec, the error would be a decode failure
    // with no rank attribution — the CRC message proves the frame was
    // rejected at the wire.
    let (r2, m2) = &results[2];
    let e = r2.as_ref().expect_err("rank 2 must reject rank 1's corrupted frame");
    let msg = format!("{e}");
    assert!(msg.contains("crc mismatch"), "CRC must reject the frame: {msg}");
    assert!(msg.contains("rank 1"), "error must name the sender: {msg}");
    assert!(m2.corrupt_frames > 0, "receiver metrics must count the corrupt frame");
    // Nobody downstream of the corruption can finish the ring.
    for (rank, (r, _)) in results.iter().enumerate() {
        if rank != FAULTY {
            assert!(r.is_err(), "rank {rank} cannot complete with rank 1 corrupting");
        }
    }
}

/// Hierarchical chaos, leader death: over a 2×2 node-grouped topology
/// the leader of node 1 (rank 2) dies before its first wire operation
/// mid-`Algo::Hier` allreduce. Every rank — the dead leader, its starved
/// follower, and the whole remote node — must resolve to a typed
/// `Timeout`/`Transport` error within its armed deadline; no rank may
/// hang or panic.
#[test]
fn chaos_hier_leader_death_fails_all_ranks_within_deadline() {
    use zccl::topology::Topology;
    // blocked(2, 2): nodes {0, 1} and {2, 3}; leaders 0 and 2.
    let dead = 2usize;
    let plan = FaultPlan::new(chaos_seed()).kill_after(0);
    let t0 = Instant::now();
    let results: Vec<Result<Vec<f32>, Error>> =
        run_chaos(plans_for(CHAOS_RANKS, dead, plan), move |c| {
            let topo = Topology::blocked(2, 2);
            let mode = Mode::hier(CompressorKind::FzLight, ErrorBound::Abs(1e-3));
            let mut ctx = CollCtx::over_nodes(c, mode, topo).unwrap();
            ctx.set_timeout(Some(Duration::from_millis(400)));
            let x = chaos_input(ctx.rank());
            ctx.allreduce(&x, ReduceOp::Sum)
        });
    assert!(t0.elapsed() < Duration::from_secs(10), "hier ranks must fail promptly");
    for (rank, r) in results.iter().enumerate() {
        let e = r.as_ref().expect_err("no rank can finish with the node-1 leader dead");
        if rank == dead {
            assert!(
                format!("{e}").contains("killed by fault plan"),
                "dead leader reports its own death: {e}"
            );
        } else {
            assert!(
                matches!(e, Error::Timeout { .. } | Error::Transport(_)),
                "rank {rank}: want Timeout or Transport, got {e:?}"
            );
        }
    }
}

/// Hierarchical chaos, follower death + abort fence across the group
/// translation: rank 3 — a *follower*, never on the leader tier — dies
/// instantly. Only its own leader (rank 2) talks to it, so rank 2 is
/// armed with a short deadline while every other rank gets one far
/// longer than the test bound. The remote node can therefore only fail
/// promptly if rank 2's abort poison crosses the `GroupTransport`-
/// translated leader tier — which is exactly what must happen: all
/// survivors fail typed well before their own deadlines, and at least
/// one observes the fence (an abort naming a peer, counted in
/// `Metrics::aborts_observed`).
#[test]
fn chaos_hier_follower_death_abort_fence_crosses_group_transport() {
    use zccl::topology::Topology;
    let dead = 3usize; // follower on node 1; its leader is rank 2
    let plan = FaultPlan::new(chaos_seed()).kill_after(0);
    let t0 = Instant::now();
    let results: Vec<(Result<Vec<f32>, Error>, Metrics)> =
        run_chaos(plans_for(CHAOS_RANKS, dead, plan), move |c| {
            let topo = Topology::blocked(2, 2);
            let mode = Mode::hier(CompressorKind::FzLight, ErrorBound::Abs(1e-3));
            let mut ctx = CollCtx::over_nodes(c, mode, topo).unwrap();
            // Only the dead follower's leader starves directly; everyone
            // else would ride out 30 s if the fence did not propagate.
            let ms = if ctx.rank() == 2 { 300 } else { 30_000 };
            ctx.set_timeout(Some(Duration::from_millis(ms)));
            let x = chaos_input(ctx.rank());
            (ctx.allreduce(&x, ReduceOp::Sum), *ctx.metrics())
        });
    assert!(
        t0.elapsed() < Duration::from_secs(15),
        "the abort fence must beat the survivors' 30 s deadlines"
    );
    for (rank, (r, _)) in results.iter().enumerate() {
        let e = r.as_ref().expect_err("no rank can finish with a follower dead");
        if rank == dead {
            assert!(
                format!("{e}").contains("killed by fault plan"),
                "dead follower reports its own death: {e}"
            );
        } else {
            assert!(
                matches!(e, Error::Timeout { .. } | Error::Transport(_)),
                "rank {rank}: want Timeout or Transport, got {e:?}"
            );
        }
    }
    let fenced = results.iter().enumerate().any(|(rank, (r, m))| {
        rank != dead
            && rank != 2
            && m.aborts_observed > 0
            && matches!(r, Err(e) if format!("{e}").contains("abort from rank"))
    });
    assert!(fenced, "some remote-node rank must fail via the propagated abort fence");
}

/// Staged-mode chaos: with version-2 frames on the wire the collective
/// behaves exactly like the fixed-width mode. A clean staged run is
/// bit-identical to the unstaged ZCCL run on the same inputs (the
/// entropy and fixed-width stages reconstruct the same quantized
/// values, and no chunk degrades to plain at this bound), and a
/// corrupted staged frame is still rejected by the CRC at delivery —
/// naming the sender — before the staged decoder ever parses it.
#[test]
fn chaos_staged_frames_clean_and_corrupt() {
    let staged_mode = chaos_mode(CompressorKind::FzLight).with_staged(true);
    let clean_unstaged = run_chaos(
        plans_for(CHAOS_RANKS, FAULTY, FaultPlan::new(chaos_seed())),
        move |c| {
            let mut ctx = CollCtx::over(c, chaos_mode(CompressorKind::FzLight));
            chaos_op(&mut ctx, 0).expect("clean run must succeed")
        },
    );
    let clean_staged = run_chaos(
        plans_for(CHAOS_RANKS, FAULTY, FaultPlan::new(chaos_seed())),
        move |c| {
            let mut ctx = CollCtx::over(c, staged_mode);
            chaos_op(&mut ctx, 0).expect("clean staged run must succeed")
        },
    );
    for (rank, (a, b)) in clean_unstaged.iter().zip(&clean_staged).enumerate() {
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "rank {rank}: staged frames must not change the reduction"
        );
    }
    let plan = FaultPlan::new(chaos_seed()).corrupt_frames(1.0);
    let results: Vec<(Result<Vec<f32>, Error>, Metrics)> =
        run_chaos(plans_for(CHAOS_RANKS, FAULTY, plan), move |c| {
            let mut ctx = CollCtx::over(c, staged_mode);
            ctx.set_timeout(Some(Duration::from_millis(400)));
            (chaos_op(&mut ctx, 0), *ctx.metrics())
        });
    let (r2, m2) = &results[2];
    let e = r2.as_ref().expect_err("rank 2 must reject rank 1's corrupted staged frame");
    let msg = format!("{e}");
    assert!(msg.contains("crc mismatch"), "CRC must reject the staged frame: {msg}");
    assert!(msg.contains("rank 1"), "error must name the sender: {msg}");
    assert!(m2.corrupt_frames > 0, "receiver metrics must count the corrupt frame");
}
