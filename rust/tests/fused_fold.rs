//! Fused-vs-unfused equivalence: [`Compressor::decompress_fold_into`]
//! must match decompress-then-[`ReduceOp::fold`] **bit for bit** — for
//! every codec (native fused kernels and default-impl codecs alike),
//! every reduce op, every field kind, tiny and empty inputs, and the
//! multithread wrappers — plus the documented corrupt-frame semantics.

use zccl::collectives::ReduceOp;
use zccl::compress::{
    Compressor, CompressorKind, ErrorBound, FzLight, MtCompressor, PipeFzLight,
};
use zccl::data::fields::{Field, FieldKind};

const OPS: [ReduceOp; 3] = [ReduceOp::Sum, ReduceOp::Max, ReduceOp::Min];

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Assert fused == unfused (bitwise) for `codec` over the given sizes.
fn check_equivalence(codec: &dyn Compressor, label: &str, sizes: &[usize]) {
    for kind in FieldKind::ALL {
        for &n in sizes {
            let f = Field::generate(kind, n, 7);
            // Some codec/size combinations may legitimately refuse to
            // compress; equivalence only applies where compression works.
            let Ok(c) = codec.compress(&f.values, ErrorBound::Abs(1e-3)) else {
                continue;
            };
            let dec = codec.decompress(&c.bytes).unwrap();
            let base = Field::generate(kind, n, 8).values;
            for op in OPS {
                let mut unfused = base.clone();
                op.fold(&mut unfused, &dec);
                let mut fused = base.clone();
                let cnt = codec.decompress_fold_into(&c.bytes, op, &mut fused).unwrap();
                assert_eq!(cnt, n, "{label} {kind:?} {op:?} n={n}: count");
                assert_eq!(
                    bits(&fused),
                    bits(&unfused),
                    "{label} {kind:?} {op:?} n={n}: fused fold must be bit-identical"
                );
            }
        }
    }
}

#[test]
fn all_codecs_fused_matches_unfused_bitwise() {
    // Small sizes exercise partial blocks, single-value chunks and empty
    // frames across every codec, including the decompress-then-fold
    // default impls (SZx, both ZFP modes).
    let sizes = [0usize, 1, 5, 31, 32, 33, 500];
    for kind in CompressorKind::ALL {
        let codec = zccl::compress::build(kind);
        check_equivalence(codec.as_ref(), kind.name(), &sizes);
    }
}

#[test]
fn fzlight_family_fused_matches_unfused_bitwise_large() {
    // The native fused kernels (single-thread, pipelined, multithread)
    // against multi-chunk inputs; chunk size 512 forces many chunks.
    let sizes = [0usize, 5119, 5120, 5121, 20_000];
    check_equivalence(&FzLight::with_chunk(512), "fzlight-512", &sizes);
    check_equivalence(&PipeFzLight::with_chunk(512), "pipe-512", &sizes);
    check_equivalence(
        &MtCompressor::with_chunk(CompressorKind::FzLight, 512),
        "mt-fzlight-512",
        &sizes,
    );
    check_equivalence(&MtCompressor::new(CompressorKind::Szx), "mt-szx", &[0, 500, 5121]);
}

#[test]
fn constant_field_exercises_broadcast_fast_path() {
    // An all-constant input compresses to constant blocks only, so the
    // fused kernel takes the broadcast run path for every block; the
    // result must still match the unfused reference bitwise.
    let data = vec![2.5f32; 10_000];
    let codec = FzLight::default();
    let c = codec.compress(&data, ErrorBound::Abs(1e-4)).unwrap();
    assert_eq!(c.stats.constant_blocks, c.stats.blocks, "field must be all-constant blocks");
    let dec = codec.decompress(&c.bytes).unwrap();
    let base = Field::generate(FieldKind::Rtm, 10_000, 3).values;
    for op in OPS {
        let mut unfused = base.clone();
        op.fold(&mut unfused, &dec);
        let mut fused = base.clone();
        codec.decompress_fold_into(&c.bytes, op, &mut fused).unwrap();
        assert_eq!(bits(&fused), bits(&unfused), "{op:?}");
    }
}

#[test]
fn corrupt_frames_error_within_documented_semantics() {
    // Documented semantics: on Err, each accumulator slot holds either
    // its original value or the correctly-folded value (an unspecified
    // subset of chunks may have been applied) — never garbage.
    let f = Field::generate(FieldKind::Hurricane, 6_000, 13);
    let codec = FzLight::with_chunk(1000);
    let c = codec.compress(&f.values, ErrorBound::Abs(1e-3)).unwrap();
    let dec = codec.decompress(&c.bytes).unwrap();
    let base = Field::generate(FieldKind::Nyx, 6_000, 14).values;
    for cut in [c.bytes.len() - 1, c.bytes.len() / 2, 40, 25] {
        let mut acc = base.clone();
        let res = codec.decompress_fold_into(&c.bytes[..cut], ReduceOp::Sum, &mut acc);
        assert!(res.is_err(), "cut {cut} must fail");
        for (i, (&a, (&b, &d))) in acc.iter().zip(base.iter().zip(&dec)).enumerate() {
            let folded = b + d;
            assert!(
                a.to_bits() == b.to_bits() || a.to_bits() == folded.to_bits(),
                "cut {cut} idx {i}: {a} is neither original {b} nor folded {folded}"
            );
        }
    }
    // Corrupt a block header mid-frame (valid chunk table, bad payload):
    // chunks before the bad one fold, the error surfaces, and every slot
    // is still either original or correctly folded. Frame layout: common
    // header (24) + chunk_values/nchunks (8) + 6-entry u32 table (24),
    // payloads concatenated from byte 56.
    let mut bad = c.bytes.clone();
    let mut off = 56usize;
    for k in 0..3 {
        let e = 32 + 4 * k;
        off += u32::from_le_bytes(bad[e..e + 4].try_into().unwrap()) as usize;
    }
    bad[off + 8] = 0xFF; // chunk 3's first block header: code length 255 > 64
    let mut acc = base.clone();
    assert!(codec.decompress_fold_into(&bad, ReduceOp::Sum, &mut acc).is_err());
    let mut changed = 0usize;
    for (i, (&a, (&b, &d))) in acc.iter().zip(base.iter().zip(&dec)).enumerate() {
        let folded = b + d;
        let is_orig = a.to_bits() == b.to_bits();
        let is_folded = a.to_bits() == folded.to_bits();
        assert!(is_orig || is_folded, "idx {i}: {a} neither original nor folded");
        if is_folded && !is_orig {
            changed += 1;
        }
    }
    assert!(changed > 0, "chunks before the corruption must have folded");

    // A wrong-length accumulator is rejected before any fold.
    let mut short = base[..100].to_vec();
    let before = short.clone();
    assert!(codec.decompress_fold_into(&c.bytes, ReduceOp::Sum, &mut short).is_err());
    assert_eq!(short, before);
    // Garbage bytes never touch the accumulator.
    let mut acc = base.clone();
    assert!(codec.decompress_fold_into(b"not a frame", ReduceOp::Sum, &mut acc).is_err());
    assert_eq!(bits(&acc), bits(&base));
}

#[test]
fn reduction_collectives_agree_across_fused_modes() {
    // End-to-end: the fused receive path must keep every compressed mode
    // within the aggregated error bound of the plain result (the modes
    // already-tested invariant, re-checked here through the new path for
    // reduce + reduce_scatter via allreduce).
    use zccl::collectives::{allreduce, run_ranks, Mode};
    use zccl::coordinator::Metrics;
    let (n, len) = (4, 2500);
    let eb = 1e-4f64;
    let want = {
        let mut acc = Field::generate(FieldKind::Cesm, len, 70).values;
        for r in 1..n {
            let src = Field::generate(FieldKind::Cesm, len, 70 + r as u64).values;
            ReduceOp::Sum.fold(&mut acc, &src);
        }
        acc
    };
    for mode in [
        Mode::plain(),
        Mode::cprp2p(CompressorKind::FzLight, ErrorBound::Abs(eb)),
        Mode::ccoll(ErrorBound::Abs(eb)),
        Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)),
        Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)).with_multithread(true),
    ] {
        let out = run_ranks(n, move |c| {
            let input = Field::generate(FieldKind::Cesm, len, 70 + c.rank() as u64).values;
            let mut m = Metrics::default();
            let r = allreduce(c, &input, ReduceOp::Sum, &mode, &mut m).unwrap();
            (r, m)
        });
        let tol = 2.0 * (n as f64) * eb + 1e-4;
        for (vals, m) in out {
            for (a, b) in vals.iter().zip(&want) {
                assert!(((a - b).abs() as f64) <= tol, "mode {:?}: {a} vs {b}", mode.algo);
            }
            // Compressed modes must attribute receive time to the fused
            // phase, not the old split Decompress/Compute pair.
            if mode.compresses() {
                assert!(
                    m.decompress_reduce_s > 0.0,
                    "mode {:?} must record DecompressReduce time",
                    mode.algo
                );
            }
        }
    }
}
