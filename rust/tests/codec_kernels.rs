//! Word-parallel codec kernel suite: proves the block-batched
//! `pack_fixed` / `unpack_fixed` kernels and the rewritten fZ-light /
//! SZx encode/decode stages are **bit-identical** to the scalar
//! `BitWriter` / `BitReader` reference layout — the frame layout is the
//! spec, and every pre-existing frame must decode unchanged.
//!
//! Four layers of evidence:
//! 1. kernel-level property tests over ALL widths 1..=64 (including the
//!    rarely-exercised 58..=64 two-limb path) and many block counts;
//! 2. whole-frame equality against an in-test reference encoder built
//!    on `BitWriter` straight from the documented chunk layout;
//! 3. hand-computed golden frames (bytes written out literally) that
//!    both encode sides must emit and both decode sides must accept —
//!    including a version-2 staged frame exercising every stage tag
//!    (entropy, fixed-width fallback, plain) in one frame;
//! 4. version interchange: version-1 frames through staged-configured
//!    wrappers and staged frames through default-configured wrappers,
//!    bit-exact both ways.

use zccl::compress::bits::{
    le, pack_fixed, pack_fixed_reference, unpack_fixed, unpack_fixed_reference, BitWriter,
};
use zccl::compress::entropy;
use zccl::compress::fzlight::{STAGE_ENTROPY, STAGE_FIXED, STAGE_PLAIN};
use zccl::compress::traits::{write_header, write_header_with_version, VERSION_STAGED};
use zccl::compress::{
    Compressor, CompressorKind, ErrorBound, FzLight, MtCompressor, PipeFzLight, Szx,
};
use zccl::coordinator::harness::codec_bench;
use zccl::data::fields::{Field, FieldKind};
use zccl::data::rng::Rng;
use zccl::util::json::Json;

// ---------------------------------------------------------------- kernels

/// Every width 1..=64 (the 58..=64 range takes the two-limb path), many
/// counts: the word-parallel packer must emit the exact BitWriter
/// stream, and both unpackers must restore the values.
#[test]
fn pack_unpack_match_reference_all_widths() {
    let mut rng = Rng::new(0xC0DEC);
    for width in 1..=64u32 {
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        for cnt in [0usize, 1, 2, 7, 8, 9, 31, 32, 33, 63, 64, 100, 257] {
            let mut vals: Vec<u64> = (0..cnt).map(|_| rng.next_u64() & mask).collect();
            // Force boundary patterns into the mix.
            if cnt >= 3 {
                vals[0] = mask;
                vals[1] = 0;
                vals[2] = mask & 0x5555_5555_5555_5555;
            }
            let mut fast = Vec::new();
            pack_fixed(&mut fast, &vals, width);
            let mut reference = Vec::new();
            pack_fixed_reference(&mut reference, &vals, width);
            assert_eq!(fast, reference, "pack width {width} cnt {cnt}");
            assert_eq!(fast.len(), (cnt * width as usize).div_ceil(8));

            let mut dec = vec![0u64; cnt];
            unpack_fixed(&fast, width, &mut dec);
            assert_eq!(dec, vals, "unpack width {width} cnt {cnt}");
            let mut dec_ref = vec![0u64; cnt];
            unpack_fixed_reference(&fast, width, &mut dec_ref);
            assert_eq!(dec_ref, vals, "reference unpack width {width} cnt {cnt}");
        }
    }
}

// ----------------------------------------------- whole-frame vs reference

/// Reference fZ-light chunk payload (version-1 / fixed-width body):
/// the documented layout realised directly with the scalar `BitWriter`
/// spec path.
fn reference_fzlight_chunk(c: &[f32], eb_abs: f64) -> Vec<u8> {
    let inv = 1.0 / (2.0 * eb_abs);
    let q: Vec<i64> = c.iter().map(|&x| (x as f64 * inv).round() as i64).collect();
    let deltas: Vec<i64> = q.windows(2).map(|w| w[1] - w[0]).collect();
    let mut p = Vec::new();
    p.extend_from_slice(&q[0].to_le_bytes());
    for db in deltas.chunks(32) {
        let maxmag = db.iter().fold(0u64, |a, d| a | d.unsigned_abs());
        if maxmag == 0 {
            p.push(0);
            continue;
        }
        let bits = 64 - maxmag.leading_zeros();
        p.push(bits as u8);
        let mut sign = 0u32;
        for (j, &d) in db.iter().enumerate() {
            sign |= u32::from(d < 0) << j;
        }
        p.extend_from_slice(&sign.to_le_bytes()[..db.len().div_ceil(8)]);
        let mut w = BitWriter::with_capacity(db.len() * 8);
        for &d in db {
            w.put_wide(d.unsigned_abs(), bits);
        }
        p.extend_from_slice(&w.finish());
    }
    p
}

/// Reference fZ-light frame encoder: the documented chunk layout
/// realised directly with the scalar `BitWriter` spec path. Any byte
/// divergence from `FzLight::compress` is a layout break.
fn reference_fzlight_frame(data: &[f32], chunk: usize, eb_abs: f64) -> Vec<u8> {
    let mut out = Vec::new();
    write_header(&mut out, CompressorKind::FzLight, data.len(), eb_abs);
    let nchunks = data.len().div_ceil(chunk);
    le::put_u32(&mut out, chunk as u32);
    le::put_u32(&mut out, nchunks as u32);
    let payloads: Vec<Vec<u8>> =
        data.chunks(chunk).map(|c| reference_fzlight_chunk(c, eb_abs)).collect();
    for p in &payloads {
        le::put_u32(&mut out, p.len() as u32);
    }
    for p in &payloads {
        out.extend_from_slice(p);
    }
    out
}

#[test]
fn fzlight_frames_match_scalar_reference_encoder() {
    for (kind, n, chunk, eb) in [
        (FieldKind::Rtm, 10_000usize, 5120usize, 1e-3f64),
        (FieldKind::Nyx, 7_001, 512, 1e-4),
        (FieldKind::Hurricane, 65, 32, 1e-2),
        (FieldKind::Cesm, 1, 5120, 1e-3),
    ] {
        let f = Field::generate(kind, n, 9);
        let reference = reference_fzlight_frame(&f.values, chunk, eb);
        for (label, frame) in [
            ("fzlight", FzLight::with_chunk(chunk).compress(&f.values, ErrorBound::Abs(eb))),
            ("pipe", PipeFzLight::with_chunk(chunk).compress(&f.values, ErrorBound::Abs(eb))),
            (
                "mt",
                MtCompressor::with_chunk(CompressorKind::FzLight, chunk)
                    .compress(&f.values, ErrorBound::Abs(eb)),
            ),
        ] {
            assert_eq!(
                frame.unwrap().bytes,
                reference,
                "{label} frame must match the scalar reference layout ({kind:?} n={n})"
            );
        }
    }
    // Empty input: header + empty chunk table, no payloads.
    let reference = reference_fzlight_frame(&[], 5120, 1e-3);
    let c = FzLight::default().compress(&[], ErrorBound::Abs(1e-3)).unwrap();
    assert_eq!(c.bytes, reference);
}

// ----------------------------------------------------------- golden frames

/// Golden fZ-light frame, worked out by hand from the layout spec:
/// data `[0, 1, 3, 2, -1]`, chunk 8, eb 0.5 (so `2eb = 1` and `q = x`).
/// One block of deltas `[1, 2, -1, -3]` → sign bits 0b1100, code length
/// 2, magnitudes `[1, 2, 1, 3]` packed LSB-first into `0xD9` (217).
fn golden_fzlight() -> (Vec<f32>, Vec<u8>, Vec<f32>) {
    let data = vec![0.0f32, 1.0, 3.0, 2.0, -1.0];
    let mut frame = Vec::new();
    write_header(&mut frame, CompressorKind::FzLight, 5, 0.5);
    le::put_u32(&mut frame, 8); // chunk_values
    le::put_u32(&mut frame, 1); // nchunks
    le::put_u32(&mut frame, 11); // payload bytes
    frame.extend_from_slice(&0i64.to_le_bytes()); // outlier q0 = 0
    frame.push(2); // code length
    frame.push(0b1100); // sign bits (deltas 2 and 3 negative)
    frame.push(217); // magnitudes 1,2,1,3 at 2 bits LSB-first
    let expect = vec![0.0f32, 1.0, 3.0, 2.0, -1.0];
    (data, frame, expect)
}

/// Golden all-constant fZ-light frame: 40 × `5.0` at eb 0.5 → outlier 5
/// plus two zero code-length bytes (blocks of 32 and 7 deltas).
fn golden_fzlight_constant() -> (Vec<f32>, Vec<u8>, Vec<f32>) {
    let data = vec![5.0f32; 40];
    let mut frame = Vec::new();
    write_header(&mut frame, CompressorKind::FzLight, 40, 0.5);
    le::put_u32(&mut frame, 64); // chunk_values
    le::put_u32(&mut frame, 1); // nchunks
    le::put_u32(&mut frame, 10); // payload bytes
    frame.extend_from_slice(&5i64.to_le_bytes()); // outlier q0 = 5
    frame.push(0); // constant block (32 deltas)
    frame.push(0); // constant block (7 deltas)
    (data, frame, vec![5.0f32; 40])
}

/// Golden SZx frame: data `[1, 2]` at eb 0.25 → μ = 1.5, residual
/// quantization step 0.5, q = [-1, +1] → tag 1, sign byte 0b01,
/// magnitude byte 0b11.
fn golden_szx() -> (Vec<f32>, Vec<u8>, Vec<f32>) {
    let data = vec![1.0f32, 2.0];
    let mut frame = Vec::new();
    write_header(&mut frame, CompressorKind::Szx, 2, 0.25);
    le::put_u32(&mut frame, 128); // chunk_values
    le::put_u32(&mut frame, 1); // nchunks
    le::put_u32(&mut frame, 7); // payload bytes
    frame.push(1); // code length
    le::put_f32(&mut frame, 1.5); // μ
    frame.push(0b01); // sign bits (first residual negative)
    frame.push(0b11); // magnitudes 1,1 at 1 bit
    (data, frame, vec![1.0f32, 2.0])
}

/// Golden constant-block SZx frame: data `[1, 2]` at eb 0.6 → the whole
/// block lies within μ ± eb, stored as tag 0 + μ alone.
fn golden_szx_constant() -> (Vec<f32>, Vec<u8>, Vec<f32>) {
    let data = vec![1.0f32, 2.0];
    let mut frame = Vec::new();
    write_header(&mut frame, CompressorKind::Szx, 2, 0.6);
    le::put_u32(&mut frame, 128); // chunk_values
    le::put_u32(&mut frame, 1); // nchunks
    le::put_u32(&mut frame, 5); // payload bytes
    frame.push(0); // constant block
    le::put_f32(&mut frame, 1.5); // μ
    (data, frame, vec![1.5f32, 1.5])
}

#[test]
fn golden_frames_encode_byte_identical() {
    let (data, frame, _) = golden_fzlight();
    for (label, got) in [
        ("fzlight", FzLight::with_chunk(8).compress(&data, ErrorBound::Abs(0.5))),
        ("pipe", PipeFzLight::with_chunk(8).compress(&data, ErrorBound::Abs(0.5))),
        (
            "mt",
            MtCompressor::with_chunk(CompressorKind::FzLight, 8)
                .compress(&data, ErrorBound::Abs(0.5)),
        ),
    ] {
        assert_eq!(got.unwrap().bytes, frame, "{label} golden frame");
    }

    let (data, frame, _) = golden_fzlight_constant();
    let got = FzLight::with_chunk(64).compress(&data, ErrorBound::Abs(0.5)).unwrap();
    assert_eq!(got.bytes, frame, "constant golden frame");
    assert_eq!(got.stats.constant_blocks, got.stats.blocks);

    let (data, frame, _) = golden_szx();
    assert_eq!(
        Szx::with_chunk(128).compress(&data, ErrorBound::Abs(0.25)).unwrap().bytes,
        frame,
        "szx golden frame"
    );
    assert_eq!(
        MtCompressor::with_chunk(CompressorKind::Szx, 128)
            .compress(&data, ErrorBound::Abs(0.25))
            .unwrap()
            .bytes,
        frame,
        "szx mt golden frame"
    );

    let (data, frame, _) = golden_szx_constant();
    assert_eq!(
        Szx::with_chunk(128).compress(&data, ErrorBound::Abs(0.6)).unwrap().bytes,
        frame,
        "szx constant golden frame"
    );
}

/// The golden bytes stand in for frames produced by earlier builds:
/// every decode path (plain, placement, fused is covered elsewhere) must
/// reconstruct them bit-exactly.
#[test]
fn golden_frames_decode_bit_exact() {
    let cases = [golden_fzlight(), golden_fzlight_constant()];
    for (i, (_, frame, expect)) in cases.iter().enumerate() {
        for decoder in [
            Box::new(FzLight::default()) as Box<dyn Compressor>,
            Box::new(PipeFzLight::default()),
            Box::new(MtCompressor::new(CompressorKind::FzLight)),
        ] {
            let d = decoder.decompress(frame).unwrap();
            assert_eq!(&d, expect, "fzlight golden {i} plain decode");
            let mut out = vec![0.0f32; expect.len()];
            decoder.decompress_into_slice(frame, &mut out).unwrap();
            assert_eq!(&out, expect, "fzlight golden {i} placement decode");
        }
    }
    for (i, (_, frame, expect)) in [golden_szx(), golden_szx_constant()].iter().enumerate() {
        let d = Szx::default().decompress(frame).unwrap();
        assert_eq!(&d, expect, "szx golden {i}");
    }
}

// ----------------------------------------------------- staged golden frame

/// Deterministic three-chunk input exercising every stage tag at chunk
/// 512, eb 0.5 (`2eb = 1`, so `q = x`): a constant plateau (the entropy
/// stage wins), a 16-bit random walk (fixed-width wins — the entropy
/// estimate overshoots the budget), and uniform ±2^35 noise whose
/// ~36-bit delta codes push fixed-width past the 2048 raw bytes (plain
/// wins). Every value is an exactly representable integer, so all three
/// reconstructions are bit-exact.
fn staged_exemplar_data() -> Vec<f32> {
    let mut data = vec![5.0f32; 512];
    let mut rng = Rng::new(0x57A6ED);
    let mut q = 0i64;
    data.extend((0..512).map(|_| {
        q += rng.below(1 << 16) as i64 - 32_768;
        q as f32
    }));
    data.extend((0..512).map(|_| ((rng.next_u64() >> 28) as i64 - (1i64 << 35)) as f32));
    data
}

/// Golden staged (version-2) fZ-light frame: the frame skeleton —
/// header, chunk table, stage tags, `raw_len` word — is written out by
/// hand from the layout spec. Fixed-width chunk bodies come from the
/// scalar reference encoder; the entropy blob comes from the public
/// `entropy::encode`, with its length and serialized table pinned
/// literally (hand-derived from the rANS normalization).
fn golden_fzlight_staged() -> (Vec<f32>, Vec<u8>, Vec<f32>) {
    let data = staged_exemplar_data();
    // Chunk 0 (constant): the fixed body is the 8-byte outlier `5` plus
    // 16 zero code-length bytes. Histogram {0: 23, 5: 1} normalizes to
    // frequencies {3926, 170}; the 24-symbol stream never leaves the
    // u32 state word, so the blob is exactly table (7) + state (4) = 11
    // bytes — under fixed's 24 by more than the selection margin.
    let fixed0 = reference_fzlight_chunk(&data[..512], 0.5);
    assert_eq!(fixed0.len(), 24, "outlier + 16 constant-block tags");
    let mut p0 = vec![STAGE_ENTROPY];
    le::put_u32(&mut p0, fixed0.len() as u32);
    entropy::encode(&fixed0, &mut p0);
    assert_eq!(p0.len(), 16, "stage tag + raw_len + 11-byte blob");
    assert_eq!(
        &p0[5..12],
        &[0, 2, 0, 5, 0x56, 0xAF, 0x0A],
        "LIST table: k=2, syms [0,5], freqs [3926,170] packed 12-bit"
    );
    // Chunk 1 (random walk): near-uniform delta bytes, so the entropy
    // estimate overshoots the budget and fixed-width ships unchanged.
    let mut p1 = vec![STAGE_FIXED];
    p1.extend_from_slice(&reference_fzlight_chunk(&data[512..1024], 0.5));
    // Chunk 2 (wide noise): fixed-width overshoots the raw values, and
    // the chunk ships as plain little-endian `f32` words.
    assert!(reference_fzlight_chunk(&data[1024..], 0.5).len() > 2048, "fixed must overshoot");
    let mut p2 = vec![STAGE_PLAIN];
    for &x in &data[1024..] {
        le::put_f32(&mut p2, x);
    }
    let mut frame = Vec::new();
    write_header_with_version(&mut frame, CompressorKind::FzLight, 1536, 0.5, VERSION_STAGED);
    le::put_u32(&mut frame, 512); // chunk_values
    le::put_u32(&mut frame, 3); // nchunks
    for p in [&p0, &p1, &p2] {
        le::put_u32(&mut frame, p.len() as u32);
    }
    for p in [&p0, &p1, &p2] {
        frame.extend_from_slice(p);
    }
    let expect = data.clone();
    (data, frame, expect)
}

#[test]
fn golden_staged_frame_encodes_byte_identical_across_wrappers() {
    let (data, frame, _) = golden_fzlight_staged();
    let eb = ErrorBound::Abs(0.5);
    for (label, got) in [
        ("fzlight", FzLight::with_chunk(512).with_staged(true).compress(&data, eb)),
        ("pipe", PipeFzLight::with_chunk(512).with_staged(true).compress(&data, eb)),
        (
            "mt",
            MtCompressor::with_chunk(CompressorKind::FzLight, 512)
                .with_staged(true)
                .compress(&data, eb),
        ),
    ] {
        let got = got.unwrap();
        assert_eq!(got.bytes, frame, "{label} staged golden frame");
        assert_eq!(
            (got.stats.chunks, got.stats.entropy_chunks, got.stats.plain_chunks),
            (3, 1, 1),
            "{label} must pick one chunk per stage"
        );
    }
}

/// The staged golden bytes stand in for version-2 frames produced by
/// earlier builds: every wrapper — including default-configured ones
/// that never *encode* staged frames — must reconstruct them bit-exactly
/// through both the plain and the placement decode paths.
#[test]
fn golden_staged_frame_decodes_bit_exact_across_wrappers() {
    let (_, frame, expect) = golden_fzlight_staged();
    for decoder in [
        Box::new(FzLight::default()) as Box<dyn Compressor>,
        Box::new(PipeFzLight::default()),
        Box::new(MtCompressor::new(CompressorKind::FzLight)),
    ] {
        assert_eq!(decoder.decompress(&frame).unwrap(), expect, "staged golden plain decode");
        let mut out = vec![0.0f32; expect.len()];
        decoder.decompress_into_slice(&frame, &mut out).unwrap();
        assert_eq!(out, expect, "staged golden placement decode");
    }
}

// -------------------------------------------------- version interchange

/// Frame-version back-compat, both directions: version-1 frames decode
/// unchanged through staged-configured wrappers (decode dispatches on
/// the frame header, never the encoder flag), staged frames decode
/// through default-configured wrappers, and all three wrappers emit
/// byte-identical frames at either version.
#[test]
fn staged_and_v1_frames_interchange_across_wrappers() {
    for (kind, n, chunk) in [
        (FieldKind::Rtm, 20_000usize, 5120usize),
        (FieldKind::Cesm, 4_097, 512),
    ] {
        let f = Field::generate(kind, n, 77);
        let eb = ErrorBound::Rel(1e-3);
        let v1 = FzLight::with_chunk(chunk).compress(&f.values, eb).unwrap();
        let staged =
            FzLight::with_chunk(chunk).with_staged(true).compress(&f.values, eb).unwrap();
        assert_eq!(v1.bytes[4], 1, "version-1 header byte");
        assert_eq!(staged.bytes[4], 2, "staged header byte");
        let from_v1 = FzLight::default().decompress(&v1.bytes).unwrap();
        let from_staged = FzLight::default().decompress(&staged.bytes).unwrap();
        for (label, codec) in [
            (
                "fzlight",
                Box::new(FzLight::with_chunk(chunk).with_staged(true)) as Box<dyn Compressor>,
            ),
            ("pipe", Box::new(PipeFzLight::with_chunk(chunk).with_staged(true))),
            (
                "mt",
                Box::new(
                    MtCompressor::with_chunk(CompressorKind::FzLight, chunk).with_staged(true),
                ),
            ),
        ] {
            let enc = codec.compress(&f.values, eb).unwrap();
            assert_eq!(enc.bytes, staged.bytes, "{label} staged frame equality ({kind:?})");
            assert_eq!(
                codec.decompress(&v1.bytes).unwrap(),
                from_v1,
                "{label} staged-configured wrapper must decode v1 frames unchanged"
            );
            assert_eq!(
                codec.decompress(&staged.bytes).unwrap(),
                from_staged,
                "{label} staged decode equality"
            );
        }
        for (label, codec) in [
            ("pipe", Box::new(PipeFzLight::with_chunk(chunk)) as Box<dyn Compressor>),
            ("mt", Box::new(MtCompressor::with_chunk(CompressorKind::FzLight, chunk))),
        ] {
            assert_eq!(
                codec.decompress(&staged.bytes).unwrap(),
                from_staged,
                "default-configured {label} must decode staged frames"
            );
        }
    }
}

// ------------------------------------------------------- wide code paths

/// Drive the 58..=64-bit code widths through the whole codec stack.
/// Values are powers of two, so quantization and reconstruction are
/// exact and the roundtrip must return the input bit-for-bit.
#[test]
fn wide_codes_roundtrip_across_wrappers() {
    for k in [50u32, 57, 58, 60, 62] {
        // twoeb = 2^-41; amplitude 2^(k-41) quantizes to ±2^k, so deltas
        // have magnitude 2^k (or 2^(k+1) mid-swing) → code length k+1.
        let eb = (2.0f64).powi(-42);
        let amp = (2.0f32).powi(k as i32 - 41);
        let data: Vec<f32> = (0..40usize).map(|i| [0.0, amp, 0.0, -amp][i % 4]).collect();
        let reference =
            FzLight::with_chunk(100).compress(&data, ErrorBound::Abs(eb)).unwrap();
        // Block header byte: 24 header + 4 + 4 + 4 table + 8 outlier.
        let code_len = reference.bytes[44];
        assert!(
            code_len as u32 >= k + 1,
            "expected a wide code (>= {}), got {code_len}",
            k + 1
        );
        for codec in [
            Box::new(FzLight::with_chunk(100)) as Box<dyn Compressor>,
            Box::new(PipeFzLight::with_chunk(100)),
            Box::new(MtCompressor::with_chunk(CompressorKind::FzLight, 100)),
        ] {
            let c = codec.compress(&data, ErrorBound::Abs(eb)).unwrap();
            assert_eq!(c.bytes, reference.bytes, "wide frame equality (k={k})");
            let d = codec.decompress(&c.bytes).unwrap();
            assert_eq!(d, data, "wide roundtrip must be exact (k={k})");
        }

        // SZx: residuals ±2^k around μ → same wide code lengths.
        let szx_data = vec![0.0f32, (2.0f32).powi(k as i32 - 40)];
        let c = Szx::with_chunk(128).compress(&szx_data, ErrorBound::Abs(eb)).unwrap();
        assert_eq!(c.bytes[36], (k + 1) as u8, "szx code length (k={k})");
        let d = Szx::default().decompress(&c.bytes).unwrap();
        assert_eq!(d, szx_data, "szx wide roundtrip must be exact (k={k})");
    }

    // Width 64: a saturated quantizer (|q| = 2^63) produces the maximal
    // magnitude; the decoder's wrapping sign flip restores it exactly.
    let eb = (2.0f64).powi(-42);
    let data = vec![0.0f32, -(2.0f32).powi(22)];
    let reference = FzLight::with_chunk(8).compress(&data, ErrorBound::Abs(eb)).unwrap();
    assert_eq!(reference.bytes[44], 64, "fzlight code length must be 64");
    for codec in [
        Box::new(FzLight::with_chunk(8)) as Box<dyn Compressor>,
        Box::new(PipeFzLight::with_chunk(8)),
        Box::new(MtCompressor::with_chunk(CompressorKind::FzLight, 8)),
    ] {
        let c = codec.compress(&data, ErrorBound::Abs(eb)).unwrap();
        assert_eq!(c.bytes, reference.bytes, "64-bit frame equality");
        assert_eq!(codec.decompress(&c.bytes).unwrap(), data, "64-bit roundtrip");
    }
    let szx_data = vec![(2.0f32).powi(23), 0.0];
    let c = Szx::with_chunk(128).compress(&szx_data, ErrorBound::Abs(eb)).unwrap();
    assert_eq!(c.bytes[36], 64, "szx code length must be 64");
    assert_eq!(Szx::default().decompress(&c.bytes).unwrap(), szx_data, "szx 64-bit roundtrip");
}

// -------------------------------------------------------- bench contract

/// Tier-1 guard for the CI `zccl bench codec` step: the library driver
/// must emit JSON that parses and carries the `speedup_vs_reference`
/// trajectory field, per-codec comp/decomp throughput rows, per-stage
/// (quantize / pack / entropy) throughput rows, and the staged-vs-fixed
/// ratio contract on the synthetic low/high-entropy datasets.
#[test]
fn bench_codec_json_parses_with_speedup_field() {
    let (tables, summary) = codec_bench(1 << 14, 0.002);
    assert_eq!(tables.len(), 4, "throughput + bit-kernel + stages + staged tables");
    let parsed = Json::parse(&summary.to_string()).expect("BENCH_codec.json must parse");
    let speedup = parsed
        .get("speedup_vs_reference")
        .and_then(Json::as_f64)
        .expect("speedup_vs_reference field");
    assert!(speedup > 0.0, "speedup must be a positive ratio, got {speedup}");
    let rows = parsed.get("codecs").and_then(Json::as_arr).expect("codecs array");
    assert_eq!(rows.len(), 8, "2 codecs x 2 datasets x 2 bounds");
    for row in rows {
        assert!(row.get("comp_gbps").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(row.get("decomp_gbps").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(row.get("ratio").and_then(Json::as_f64).unwrap() > 0.0);
    }

    // Per-stage throughput: quantize, pack, and entropy each report
    // positive encode and decode GB/s.
    let stages = parsed.get("stages").and_then(Json::as_arr).expect("stages array");
    let names: Vec<&str> =
        stages.iter().map(|r| r.get("stage").and_then(Json::as_str).unwrap()).collect();
    assert_eq!(names, ["quantize", "pack", "entropy"], "one row per codec stage");
    for row in stages {
        assert!(row.get("enc_gbps").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(row.get("dec_gbps").and_then(Json::as_f64).unwrap() > 0.0);
    }

    // Staged-vs-fixed contract on the deterministic synthetic datasets:
    // the entropy stage must buy >= 15% on the low-entropy plateau
    // field, and adaptive selection must never lose more than the
    // per-chunk stage tag on either dataset.
    let staged = parsed.get("staged").and_then(Json::as_arr).expect("staged array");
    assert_eq!(staged.len(), 2, "low- and high-entropy datasets");
    for row in staged {
        let dataset = row.get("dataset").and_then(Json::as_str).unwrap();
        let fixed_bytes = row.get("fixed_bytes").and_then(Json::as_f64).unwrap();
        let staged_bytes = row.get("staged_bytes").and_then(Json::as_f64).unwrap();
        let chunks = row.get("chunks").and_then(Json::as_f64).unwrap();
        assert!(
            staged_bytes <= fixed_bytes + chunks,
            "never-worse on {dataset}: staged {staged_bytes} vs fixed {fixed_bytes} + \
             {chunks} tag bytes"
        );
        assert!(row.get("comp_gbps").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(row.get("decomp_gbps").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(row.get("fixed_ratio").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(row.get("staged_ratio").and_then(Json::as_f64).unwrap() > 0.0);
        let gain = row.get("gain").and_then(Json::as_f64).unwrap();
        if dataset == "low-entropy" {
            assert!(gain >= 1.15, "entropy stage must beat fixed-width by >= 15%, got {gain}");
            assert!(
                row.get("entropy_chunks").and_then(Json::as_f64).unwrap() > 0.0,
                "low-entropy chunks must take the entropy stage"
            );
        }
    }
}
