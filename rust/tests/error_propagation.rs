//! Statistical validation of the paper's §3.2 error-propagation theory.
//!
//! - **Theorem 1 / Corollary 1**: the Sum-reduced error over n ranks is
//!   ~N(0, nσ²); within ±(2/3)√n·ê with probability ≈95.44% under
//!   ê ≈ 3σ. We check the √n scaling of the measured error std and the
//!   coverage probability.
//! - **Corollary 2**: Average shrinks the error std by √n vs Sum (variance
//!   by n).
//! - **Theorem 2**: Max/Min error variance stays bounded by
//!   (2 − (n+2)/2ⁿ)σ² < 2σ² — i.e. it does NOT grow with n.
//!
//! The "compressor" here is the real fZ-light quantizer, so the error
//! distribution is the real quantization error, not injected noise.

use zccl::collectives::{allreduce, run_ranks, Mode, ReduceOp};
use zccl::compress::{CompressorKind, ErrorBound};
use zccl::coordinator::Metrics;
use zccl::data::fields::{Field, FieldKind};

const EB: f64 = 1e-3;

/// Run a ZCCL Sum/Avg/... allreduce at n ranks and return the pointwise
/// errors vs the exact serial reduction.
fn reduce_errors(n: usize, len: usize, op: ReduceOp, seed: u64) -> Vec<f64> {
    let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(EB));
    let out = run_ranks(n, move |c| {
        let f = Field::generate(FieldKind::Nyx, len, seed + c.rank() as u64);
        let mut m = Metrics::default();
        allreduce(c, &f.values, op, &mode, &mut m).unwrap()
    });
    let mut exact = Field::generate(FieldKind::Nyx, len, seed).values;
    for r in 1..n {
        let f = Field::generate(FieldKind::Nyx, len, seed + r as u64);
        op.fold(&mut exact, &f.values);
    }
    op.finish(&mut exact, n);
    out[0].iter().zip(&exact).map(|(a, b)| *a as f64 - *b as f64).collect()
}

fn std_dev(errs: &[f64]) -> f64 {
    let n = errs.len() as f64;
    let mu = errs.iter().sum::<f64>() / n;
    (errs.iter().map(|e| (e - mu) * (e - mu)).sum::<f64>() / n).sqrt()
}

#[test]
fn theorem1_sum_error_std_grows_like_sqrt_n() {
    let len = 1 << 15;
    let s2 = std_dev(&reduce_errors(2, len, ReduceOp::Sum, 100));
    let s8 = std_dev(&reduce_errors(8, len, ReduceOp::Sum, 100));
    // σ(8 ranks)/σ(2 ranks) should be ≈ √(8/2) = 2 — allow a wide band
    // (the chain includes one extra allgather compression).
    let ratio = s8 / s2;
    assert!(
        (1.2..4.0).contains(&ratio),
        "sum error std should grow ~sqrt(n): sigma2={s2:.2e} sigma8={s8:.2e} ratio={ratio:.2}"
    );
    // And both stay far below the deterministic worst case n·ê.
    assert!(s8 < 8.0 * EB);
}

#[test]
fn theorem1_95pct_coverage_with_measured_sigma() {
    // Theorem 1 proper: err_sum ~ N(0, k·σ²) over a k-hop aggregation
    // chain, so |err| <= 2·√k·σ w.p. 95.44%. The paper's Corollary 1
    // substitutes ê ≈ 3σ, which holds for their near-normal compressor
    // error; fZ-light's quantization error on our synthetic fields is
    // closer to uniform (σ = ê/√3 ≈ 0.58ê > ê/3), so we test the theorem
    // with the MEASURED single-hop σ (that is exactly what the theorem
    // claims — the corollary's constant is a distributional assumption;
    // `zccl bench fig5` reports how close each codec's error comes to
    // normal).
    let n = 8;
    let len = 1 << 15;
    // Measured single-compression error std on this data.
    let one = {
        use zccl::compress::{Compressor, FzLight};
        let f = Field::generate(FieldKind::Nyx, len, 200);
        let codec = FzLight::default();
        let dec = codec
            .decompress(&codec.compress(&f.values, ErrorBound::Abs(EB)).unwrap().bytes)
            .unwrap();
        std_dev(
            &f.values
                .iter()
                .zip(&dec)
                .map(|(a, b)| *a as f64 - *b as f64)
                .collect::<Vec<_>>(),
        )
    };
    let errs = reduce_errors(n, len, ReduceOp::Sum, 200);
    // Chain length: n-1 reduce-scatter hops + 1 allgather compression.
    let k = n as f64;
    let bound = 2.0 * k.sqrt() * one;
    let covered = errs.iter().filter(|e| e.abs() <= bound).count() as f64 / errs.len() as f64;
    assert!(
        covered >= 0.90,
        "coverage {covered:.4} below ~95% for 2·sqrt(k)·sigma = {bound:.2e} (sigma1 {one:.2e})"
    );
    // The deterministic envelope k·ê must cover everything.
    let max = errs.iter().fold(0.0f64, |m, e| m.max(e.abs()));
    assert!(max <= k * EB * 1.01 + 1e-6);
}

#[test]
fn corollary2_average_shrinks_error() {
    // Corollary 2 concerns the aggregation chain itself, so test it on
    // the binomial reduce-to-root (no final allgather re-compression,
    // which would add a fresh ±ê to the averaged values and mask the
    // 1/n shrink — allreduce(Avg) does pay that extra ê).
    use zccl::collectives::reduce;
    let len = 1 << 14;
    let n = 8;
    let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(EB));
    let run = move |op: ReduceOp, seed: u64| -> Vec<f64> {
        let out = run_ranks(n, move |c| {
            let f = Field::generate(FieldKind::Nyx, len, seed + c.rank() as u64);
            let mut m = Metrics::default();
            reduce(c, &f.values, op, 0, &mode, &mut m).unwrap()
        });
        let mut exact = Field::generate(FieldKind::Nyx, len, seed).values;
        for r in 1..n {
            let f = Field::generate(FieldKind::Nyx, len, seed + r as u64);
            op.fold(&mut exact, &f.values);
        }
        op.finish(&mut exact, n);
        out[0]
            .as_ref()
            .unwrap()
            .iter()
            .zip(&exact)
            .map(|(a, b)| *a as f64 - *b as f64)
            .collect()
    };
    let sum_std = std_dev(&run(ReduceOp::Sum, 300));
    let avg_std = std_dev(&run(ReduceOp::Avg, 300));
    let ratio = sum_std / avg_std.max(1e-18);
    // Avg = Sum / n: the error std shrinks by exactly n.
    assert!(
        ratio > n as f64 * 0.8 && ratio < n as f64 * 1.2,
        "avg must shrink error ~{n}x: sum {sum_std:.2e} avg {avg_std:.2e} ratio {ratio:.1}"
    );
}

#[test]
fn theorem2_max_error_does_not_grow_with_n() {
    let len = 1 << 14;
    let s2 = std_dev(&reduce_errors(2, len, ReduceOp::Max, 400));
    let s16 = std_dev(&reduce_errors(16, len, ReduceOp::Max, 400));
    // Theorem 2: variance bounded by 2σ² regardless of n — so the std at
    // 16 ranks must stay within a small constant of the 2-rank std, not
    // scale like √8 ≈ 2.8.
    assert!(
        s16 < 2.0 * s2 + 0.2 * EB,
        "max-op error must not accumulate: sigma2={s2:.2e} sigma16={s16:.2e}"
    );
    // And stays near a single quantization error.
    assert!(s16 < 2.0 * EB, "sigma16 {s16:.2e}");
}

#[test]
fn zccl_data_movement_error_is_single_eb_regardless_of_n() {
    // §3.1.1: data movement compresses once, so the bcast error at depth
    // log2(n) equals the single-compression error — identical for n=2 and
    // n=16.
    for n in [2usize, 16] {
        let payload = Field::generate(FieldKind::Cesm, 1 << 14, 500).values;
        let want = payload.clone();
        let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(EB));
        let out = run_ranks(n, move |c| {
            let data = (c.rank() == 0).then(|| payload.clone());
            let mut m = Metrics::default();
            zccl::collectives::bcast(c, data.as_deref(), 0, &mode, &mut m).unwrap()
        });
        for o in out {
            let max_err = o
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs() as f64)
                .fold(0.0, f64::max);
            assert!(max_err <= EB * 1.001 + 1e-7, "n={n}: max err {max_err:.2e}");
        }
    }
}
