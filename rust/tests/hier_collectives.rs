//! Hierarchical-collective property suite.
//!
//! 1. `Algo::Hier` allgather / bcast / scatter are **bit-identical** to
//!    flat `Algo::Zccl` on the same communicator for every node shape
//!    (1×n, n×1, uneven nodes, non-power-of-two leader counts): the
//!    leaders preserve the flat per-rank frame boundaries, so the decoded
//!    values cannot differ.
//! 2. Hier allreduce is bit-identical to flat `Zccl` run over the
//!    **leader group** on the node-reduced inputs (the inter tier IS the
//!    flat schedule, via `GroupTransport`) — and therefore to flat `Zccl`
//!    outright when every node holds one rank.
//! 3. The 4-node × 4-rank acceptance: each node's data is compressed
//!    exactly once, by its leader (codec counters), every frame crossing
//!    the slow tier travels leader↔leader (fabric tier ledger), and
//!    followers never touch the codec.
//! 4. Warm hierarchical calls stay allocation-free
//!    (`PoolStats` / `PacketPoolStats`).

use zccl::collectives::{run_ranks, run_ranks_on, CollCtx, Mode, ReduceOp};
use zccl::compress::{CompressorKind, ErrorBound};
use zccl::data::fields::{Field, FieldKind};
use zccl::topology::Topology;

const EB: f64 = 1e-3;

fn inter_mode() -> Mode {
    Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(EB))
}

fn hier_mode() -> Mode {
    Mode::hier(CompressorKind::FzLight, ErrorBound::Abs(EB))
}

/// The node shapes the suite sweeps: single node (1×n), flat (n×1),
/// uneven nodes, even blocks, and a non-power-of-two leader count.
fn shapes() -> Vec<Topology> {
    vec![
        Topology::grouped(&[5]).unwrap(),       // 1 node x 5 ranks
        Topology::flat(5),                      // 5 nodes x 1 rank
        Topology::grouped(&[3, 1, 2]).unwrap(), // uneven
        Topology::blocked(2, 2),                // 2 x 2
        Topology::grouped(&[2, 2, 2]).unwrap(), // 3 leaders (non-pow2)
    ]
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn rank_chunk(rank: usize, len: usize) -> Vec<f32> {
    Field::generate(FieldKind::Cesm, len, 4000 + rank as u64).values
}

#[test]
fn hier_allgather_bit_identical_to_flat_zccl() {
    for topo in shapes() {
        let n = topo.ranks();
        // Unequal chunk lengths, including an empty contribution.
        let len_of = |r: usize| if r == 1 { 0 } else { 200 + 37 * r };
        let flat = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, inter_mode());
            let mine = rank_chunk(ctx.rank(), len_of(ctx.rank()));
            ctx.allgather(&mine).unwrap()
        });
        let t2 = topo.clone();
        let (hier, report) = run_ranks_on(&topo, move |c| {
            let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
            let mine = rank_chunk(ctx.rank(), len_of(ctx.rank()));
            ctx.allgather(&mine).unwrap()
        });
        for (rank, (h, f)) in hier.iter().zip(&flat).enumerate() {
            assert_eq!(bits(h), bits(f), "topo {topo:?} rank {rank}");
        }
        for &(a, b) in &report.inter_pairs {
            assert!(
                topo.is_leader(a) && topo.is_leader(b),
                "slow tier crossed by non-leaders {a}->{b} in {topo:?}"
            );
        }
    }
}

#[test]
fn hier_bcast_bit_identical_to_flat_zccl() {
    for topo in shapes() {
        let n = topo.ranks();
        // Roots covering a leader, a follower (where one exists), and the
        // last rank.
        for root in [0, 1 % n, n - 1] {
            let flat = run_ranks(n, move |c| {
                let mut ctx = CollCtx::over(c, inter_mode());
                let data = (c.rank() == root).then(|| rank_chunk(99, 3000));
                ctx.bcast(data.as_deref(), root).unwrap()
            });
            let t2 = topo.clone();
            let (hier, report) = run_ranks_on(&topo, move |c| {
                let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
                let data = (c.rank() == root).then(|| rank_chunk(99, 3000));
                (ctx.bcast(data.as_deref(), root).unwrap(), ctx.compress_calls())
            });
            for (rank, ((h, compresses), f)) in hier.iter().zip(&flat).enumerate() {
                assert_eq!(bits(h), bits(f), "topo {topo:?} root {root} rank {rank}");
                let want = u64::from(rank == root);
                assert_eq!(
                    *compresses, want,
                    "only the root compresses (topo {topo:?} root {root} rank {rank})"
                );
            }
            for &(a, b) in &report.inter_pairs {
                assert!(topo.is_leader(a) && topo.is_leader(b), "{topo:?} root {root}");
            }
        }
    }
}

#[test]
fn hier_scatter_bit_identical_to_flat_zccl() {
    for topo in shapes() {
        let n = topo.ranks();
        for root in [0, n - 1] {
            for len in [1001usize, 3] {
                // len=3 < n: some ranks own empty chunks.
                let flat = run_ranks(n, move |c| {
                    let mut ctx = CollCtx::over(c, inter_mode());
                    let data = (c.rank() == root).then(|| rank_chunk(7, len));
                    ctx.scatter(data.as_deref(), root).unwrap()
                });
                let t2 = topo.clone();
                let (hier, report) = run_ranks_on(&topo, move |c| {
                    let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
                    let data = (c.rank() == root).then(|| rank_chunk(7, len));
                    ctx.scatter(data.as_deref(), root).unwrap()
                });
                for (rank, (h, f)) in hier.iter().zip(&flat).enumerate() {
                    assert_eq!(
                        bits(h),
                        bits(f),
                        "topo {topo:?} root {root} len {len} rank {rank}"
                    );
                }
                for &(a, b) in &report.inter_pairs {
                    assert!(topo.is_leader(a) && topo.is_leader(b), "{topo:?} root {root}");
                }
            }
        }
    }
}

/// Hier allreduce's inter tier IS the flat ZCCL allreduce over the leader
/// group: running flat ZCCL on a leaders-only fabric fed the node-reduced
/// inputs must reproduce the hierarchical result bit for bit.
#[test]
fn hier_allreduce_bit_identical_to_leader_tier_reference() {
    let len = 2500;
    for topo in shapes() {
        let n = topo.ranks();
        for op in [ReduceOp::Sum, ReduceOp::Max] {
            let t2 = topo.clone();
            let (hier, _) = run_ranks_on(&topo, move |c| {
                let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
                let input = rank_chunk(ctx.rank(), len);
                ctx.allreduce(&input, op).unwrap()
            });
            // Node-reduced inputs, folded in ascending member order — the
            // same order the leader folds raw member partials.
            let nodes = topo.nodes();
            let node_sums: Vec<Vec<f32>> = (0..nodes)
                .map(|j| {
                    let members = topo.members(j);
                    let mut acc = rank_chunk(members[0], len);
                    for &r in &members[1..] {
                        op.fold(&mut acc, &rank_chunk(r, len));
                    }
                    acc
                })
                .collect();
            let reference = run_ranks(nodes, move |c| {
                let mut ctx = CollCtx::over(c, inter_mode());
                let me = ctx.rank();
                ctx.allreduce(&node_sums[me], op).unwrap()
            });
            for (rank, h) in hier.iter().enumerate() {
                assert_eq!(bits(h), bits(&reference[0]), "topo {topo:?} {op:?} rank {rank}");
            }
        }
    }
}

/// With one rank per node the hierarchy is the identity: hier == flat
/// ZCCL on the very same communicator, bit for bit.
#[test]
fn hier_allreduce_flat_topology_matches_flat_zccl() {
    let (n, len) = (5, 3000);
    let flat = run_ranks(n, move |c| {
        let mut ctx = CollCtx::over(c, inter_mode());
        let input = rank_chunk(ctx.rank(), len);
        ctx.allreduce(&input, ReduceOp::Sum).unwrap()
    });
    let topo = Topology::flat(n);
    let (hier, report) = run_ranks_on(&topo, move |c| {
        let mut ctx = CollCtx::over_nodes(c, hier_mode(), Topology::flat(5)).unwrap();
        let input = rank_chunk(ctx.rank(), len);
        ctx.allreduce(&input, ReduceOp::Sum).unwrap()
    });
    for (h, f) in hier.iter().zip(&flat) {
        assert_eq!(bits(h), bits(f));
    }
    // Every rank is a leader, so crossings are unrestricted — but the
    // ledger must have seen traffic (everything is inter-node here).
    assert!(report.tier.inter_bytes > 0);
    assert_eq!(report.tier.intra_bytes, 0);
}

/// A hierarchical mode without an installed topology degenerates to flat
/// ZCCL (Topology::flat default).
#[test]
fn hier_without_topology_degenerates_to_flat() {
    let (n, len) = (4, 1500);
    let flat = run_ranks(n, move |c| {
        let mut ctx = CollCtx::over(c, inter_mode());
        let input = rank_chunk(ctx.rank(), len);
        ctx.allreduce(&input, ReduceOp::Sum).unwrap()
    });
    let hier = run_ranks(n, move |c| {
        let mut ctx = CollCtx::over(c, hier_mode());
        let input = rank_chunk(ctx.rank(), len);
        ctx.allreduce(&input, ReduceOp::Sum).unwrap()
    });
    for (h, f) in hier.iter().zip(&flat) {
        assert_eq!(bits(h), bits(f));
    }
}

/// Accuracy: the hierarchical sum stays inside the compressed-chain error
/// envelope of the LEADER ring (L hops), not the full rank count — the
/// intra tier is exact. Avg finishes with the total rank count.
#[test]
fn hier_allreduce_error_envelope_and_avg() {
    let topo = Topology::blocked(4, 4);
    let (n, len) = (topo.ranks(), 4096);
    for op in [ReduceOp::Sum, ReduceOp::Avg] {
        let t2 = topo.clone();
        let (out, _) = run_ranks_on(&topo, move |c| {
            let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
            let input = rank_chunk(ctx.rank(), len);
            ctx.allreduce(&input, op).unwrap()
        });
        let mut exact = rank_chunk(0, len);
        for r in 1..n {
            op.fold(&mut exact, &rank_chunk(r, len));
        }
        op.finish(&mut exact, n);
        // The reduce-scatter chain over L = 4 leaders injects at most
        // (L-1)·ê into the (pre-finish) partial — scaled by 1/n for Avg —
        // and the allgather hop compresses the finished chunk once more
        // at full ê.
        let scale = if op == ReduceOp::Avg { 1.0 / n as f64 } else { 1.0 };
        let tol = (topo.nodes() as f64 - 1.0) * EB * scale + EB * 1.01 + 1e-5;
        for o in &out {
            assert_eq!(o.len(), len);
            for (a, b) in o.iter().zip(&exact) {
                assert!(((a - b).abs() as f64) <= tol, "{op:?}: {a} vs {b} tol {tol}");
            }
        }
        for o in &out[1..] {
            assert_eq!(bits(o), bits(&out[0]), "all ranks identical ({op:?})");
        }
    }
}

/// The ISSUE acceptance: over a 4-node × 4-rank fabric, each node's data
/// is compressed exactly once per frame, by its leader; followers never
/// touch the codec; every slow-tier crossing is leader↔leader.
#[test]
fn acceptance_4x4_compress_once_per_node_leaders_only() {
    let topo = Topology::blocked(4, 4);
    let nodes = topo.nodes();
    let len = 4096;

    // Allreduce: each leader compresses L frames (L-1 reduce-scatter
    // rounds + its allgather chunk), followers none, and nobody decodes
    // anything off the fast tier except leaders.
    let t2 = topo.clone();
    let (out, report) = run_ranks_on(&topo, move |c| {
        let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
        let input = rank_chunk(ctx.rank(), len);
        let r = ctx.allreduce(&input, ReduceOp::Sum).unwrap();
        let pool = ctx.pool_stats();
        (r, ctx.compress_calls(), pool.placement_decodes + pool.staged_decodes)
    });
    for (rank, (_, compresses, decodes)) in out.iter().enumerate() {
        if topo.is_leader(rank) {
            assert_eq!(
                *compresses,
                nodes as u64,
                "leader {rank} compresses one frame per inter-tier hop"
            );
            assert!(*decodes > 0, "leader {rank} decodes");
        } else {
            assert_eq!(*compresses, 0, "follower {rank} must never compress");
            assert_eq!(*decodes, 0, "follower {rank} must never decompress");
        }
    }
    assert!(report.tier.inter_bytes > 0, "leaders exchanged compressed frames");
    assert!(report.tier.intra_bytes > 0, "members exchanged raw windows");
    assert!(!report.inter_pairs.is_empty());
    for &(a, b) in &report.inter_pairs {
        assert!(
            topo.is_leader(a) && topo.is_leader(b),
            "slow tier crossed by non-leaders: {a} -> {b}"
        );
    }
    for o in &out[1..] {
        assert_eq!(bits(&o.0), bits(&out.first().unwrap().0), "MPI semantics");
    }

    // Allgather: exactly one compression per member chunk, all at the
    // leader — "compress once per node" in its purest form.
    let t3 = topo.clone();
    let (ag, report) = run_ranks_on(&topo, move |c| {
        let mut ctx = CollCtx::over_nodes(c, hier_mode(), t3.clone()).unwrap();
        let mine = rank_chunk(ctx.rank(), 700);
        ctx.allgather(&mine).unwrap();
        ctx.compress_calls()
    });
    for (rank, compresses) in ag.iter().enumerate() {
        let want = if topo.is_leader(rank) {
            topo.members(topo.node_of(rank)).len() as u64
        } else {
            0
        };
        assert_eq!(*compresses, want, "rank {rank}: one compression per node chunk");
    }
    for &(a, b) in &report.inter_pairs {
        assert!(topo.is_leader(a) && topo.is_leader(b));
    }
}

/// Warm hierarchical allreduce performs zero scratch-pool growth and
/// zero packet-pool allocations — the satellite regression mirroring the
/// flat warm-path tests.
#[test]
fn warm_hier_allreduce_is_allocation_free() {
    let topo = Topology::blocked(2, 2);
    let len = 5000;
    let t2 = topo.clone();
    let (ok, _) = run_ranks_on(&topo, move |c| {
        let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
        let input = rank_chunk(ctx.rank(), len);
        let mut out = Vec::new();

        // Deterministically pre-warm the fabric-shared packet pool past
        // any possible concurrent demand, so the post-warm-up counter
        // cannot depend on thread interleaving (same pattern as the flat
        // placement-decode regression).
        let warmed: Vec<Vec<u8>> = (0..16)
            .map(|_| {
                let mut b = ctx.transport().lease();
                b.reserve_exact(64 << 10);
                b
            })
            .collect();
        ctx.barrier().unwrap();
        for b in warmed {
            ctx.transport().recycle(b);
        }

        ctx.allreduce_into(&input, ReduceOp::Sum, &mut out).unwrap();
        ctx.allreduce_into(&input, ReduceOp::Sum, &mut out).unwrap();
        ctx.barrier().unwrap();
        let warm = ctx.pool_stats();
        let warm_packets = ctx.packet_stats().allocated;
        let warm_builds = ctx.codec_builds();

        for _ in 0..3 {
            ctx.allreduce_into(&input, ReduceOp::Sum, &mut out).unwrap();
        }
        ctx.barrier().unwrap();
        let after = ctx.pool_stats();
        assert_eq!(
            after.byte_buffers_created, warm.byte_buffers_created,
            "warm hier allreduce must not create byte buffers"
        );
        assert_eq!(
            after.f32_buffers_created, warm.f32_buffers_created,
            "warm hier allreduce must not create f32 buffers"
        );
        assert_eq!(
            ctx.packet_stats().allocated,
            warm_packets,
            "warm hier allreduce must lease every wire buffer from the pool"
        );
        assert_eq!(ctx.codec_builds(), warm_builds, "no per-iteration codec builds");
        true
    });
    assert!(ok.into_iter().all(|x| x));
}

/// Collectives without a dedicated hierarchical schedule fall back to
/// their flat ZCCL form under `Algo::Hier` — same results, no surprises.
#[test]
fn hier_fallback_collectives_match_flat_zccl() {
    let topo = Topology::blocked(2, 2);
    let (n, len) = (topo.ranks(), 1200);
    let flat = run_ranks(n, move |c| {
        let mut ctx = CollCtx::over(c, inter_mode());
        let input = rank_chunk(ctx.rank(), len);
        let rs = ctx.reduce_scatter(&input, ReduceOp::Sum).unwrap();
        let g = ctx.gather(&input, 0).unwrap();
        let a2a = ctx.alltoall(&input).unwrap();
        let red = ctx.reduce(&input, ReduceOp::Sum, 1).unwrap();
        (rs, g, a2a, red)
    });
    let t2 = topo.clone();
    let (hier, _) = run_ranks_on(&topo, move |c| {
        let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
        let input = rank_chunk(ctx.rank(), len);
        let rs = ctx.reduce_scatter(&input, ReduceOp::Sum).unwrap();
        let g = ctx.gather(&input, 0).unwrap();
        let a2a = ctx.alltoall(&input).unwrap();
        let red = ctx.reduce(&input, ReduceOp::Sum, 1).unwrap();
        (rs, g, a2a, red)
    });
    for (rank, (h, f)) in hier.iter().zip(&flat).enumerate() {
        assert_eq!(h.0 .0, f.0 .0, "reduce_scatter range, rank {rank}");
        assert_eq!(bits(&h.0 .1), bits(&f.0 .1), "reduce_scatter, rank {rank}");
        assert_eq!(
            h.1.as_deref().map(bits),
            f.1.as_deref().map(bits),
            "gather, rank {rank}"
        );
        assert_eq!(bits(&h.2), bits(&f.2), "alltoall, rank {rank}");
        assert_eq!(h.3.as_deref().map(bits), f.3.as_deref().map(bits), "reduce, rank {rank}");
    }
}

#[test]
fn topology_and_tier_mode_validation() {
    let n = 3;
    let results = run_ranks(n, move |c| {
        let mut ctx = CollCtx::over(c, hier_mode());
        // Wrong rank count is rejected.
        let bad = ctx.set_topology(Topology::flat(7));
        // Right rank count installs.
        let good = ctx.set_topology(Topology::grouped(&[2, 1]).unwrap());
        // Compressed intra tier is rejected; raw is accepted.
        let bad_intra = ctx.set_intra_mode(inter_mode());
        let good_intra = ctx.set_intra_mode(Mode::plain());
        // Keep the ranks in lockstep (no collective ran here).
        (bad.is_err(), good.is_ok(), bad_intra.is_err(), good_intra.is_ok())
    });
    for r in results {
        assert_eq!(r, (true, true, true, true));
    }
}
