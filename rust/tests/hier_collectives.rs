//! Hierarchical-collective property suite.
//!
//! 1. `Algo::Hier` allgather / bcast / scatter / gather / alltoall are
//!    **bit-identical** to flat `Algo::Zccl` on the same communicator for
//!    every node shape (1×n, n×1, uneven nodes, non-power-of-two leader
//!    counts): the leaders preserve the flat per-rank frame boundaries,
//!    so the decoded values cannot differ.
//! 2. Hier allreduce / reduce-scatter / reduce are bit-identical to flat
//!    `Zccl` run over the **leader group** on the node-reduced inputs
//!    (the inter tier IS the flat schedule, via `GroupTransport`) — and
//!    therefore to flat `Zccl` outright when every node holds one rank.
//! 3. The 4-node × 4-rank acceptance: each node's data is compressed
//!    exactly once, by its leader (codec counters), every frame crossing
//!    the slow tier travels leader↔leader (fabric tier ledger), and
//!    followers never touch the codec.
//! 4. Warm hierarchical calls stay allocation-free
//!    (`PoolStats` / `PacketPoolStats`).
//! 5. The staged (version-2) codec and the compressed intra tier compose
//!    with the hierarchy: staged hier stays bit-identical to staged flat,
//!    and a compressed fast tier keeps the error bounded while followers
//!    take over their own up-hop compression.

use zccl::collectives::{chunk_ranges, run_ranks, run_ranks_on, CollCtx, Mode, ReduceOp};
use zccl::compress::{CompressorKind, ErrorBound};
use zccl::coordinator::harness::hier_bench;
use zccl::data::fields::{Field, FieldKind};
use zccl::sim::calibrate::{MAX_SEGMENT_BYTES, MIN_SEGMENT_BYTES};
use zccl::topology::Topology;
use zccl::util::json::Json;

const EB: f64 = 1e-3;

fn inter_mode() -> Mode {
    Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(EB))
}

fn hier_mode() -> Mode {
    Mode::hier(CompressorKind::FzLight, ErrorBound::Abs(EB))
}

/// The node shapes the suite sweeps: single node (1×n), flat (n×1),
/// uneven nodes, even blocks, and a non-power-of-two leader count.
fn shapes() -> Vec<Topology> {
    vec![
        Topology::grouped(&[5]).unwrap(),       // 1 node x 5 ranks
        Topology::flat(5),                      // 5 nodes x 1 rank
        Topology::grouped(&[3, 1, 2]).unwrap(), // uneven
        Topology::blocked(2, 2),                // 2 x 2
        Topology::grouped(&[2, 2, 2]).unwrap(), // 3 leaders (non-pow2)
    ]
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn rank_chunk(rank: usize, len: usize) -> Vec<f32> {
    Field::generate(FieldKind::Cesm, len, 4000 + rank as u64).values
}

#[test]
fn hier_allgather_bit_identical_to_flat_zccl() {
    for topo in shapes() {
        let n = topo.ranks();
        // Unequal chunk lengths, including an empty contribution.
        let len_of = |r: usize| if r == 1 { 0 } else { 200 + 37 * r };
        let flat = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, inter_mode());
            let mine = rank_chunk(ctx.rank(), len_of(ctx.rank()));
            ctx.allgather(&mine).unwrap()
        });
        let t2 = topo.clone();
        let (hier, report) = run_ranks_on(&topo, move |c| {
            let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
            let mine = rank_chunk(ctx.rank(), len_of(ctx.rank()));
            ctx.allgather(&mine).unwrap()
        });
        for (rank, (h, f)) in hier.iter().zip(&flat).enumerate() {
            assert_eq!(bits(h), bits(f), "topo {topo:?} rank {rank}");
        }
        for &(a, b) in &report.inter_pairs {
            assert!(
                topo.is_leader(a) && topo.is_leader(b),
                "slow tier crossed by non-leaders {a}->{b} in {topo:?}"
            );
        }
    }
}

#[test]
fn hier_bcast_bit_identical_to_flat_zccl() {
    for topo in shapes() {
        let n = topo.ranks();
        // Roots covering a leader, a follower (where one exists), and the
        // last rank.
        for root in [0, 1 % n, n - 1] {
            let flat = run_ranks(n, move |c| {
                let mut ctx = CollCtx::over(c, inter_mode());
                let data = (c.rank() == root).then(|| rank_chunk(99, 3000));
                ctx.bcast(data.as_deref(), root).unwrap()
            });
            let t2 = topo.clone();
            let (hier, report) = run_ranks_on(&topo, move |c| {
                let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
                let data = (c.rank() == root).then(|| rank_chunk(99, 3000));
                (ctx.bcast(data.as_deref(), root).unwrap(), ctx.compress_calls())
            });
            for (rank, ((h, compresses), f)) in hier.iter().zip(&flat).enumerate() {
                assert_eq!(bits(h), bits(f), "topo {topo:?} root {root} rank {rank}");
                let want = u64::from(rank == root);
                assert_eq!(
                    *compresses, want,
                    "only the root compresses (topo {topo:?} root {root} rank {rank})"
                );
            }
            for &(a, b) in &report.inter_pairs {
                assert!(topo.is_leader(a) && topo.is_leader(b), "{topo:?} root {root}");
            }
        }
    }
}

#[test]
fn hier_scatter_bit_identical_to_flat_zccl() {
    for topo in shapes() {
        let n = topo.ranks();
        for root in [0, n - 1] {
            for len in [1001usize, 3] {
                // len=3 < n: some ranks own empty chunks.
                let flat = run_ranks(n, move |c| {
                    let mut ctx = CollCtx::over(c, inter_mode());
                    let data = (c.rank() == root).then(|| rank_chunk(7, len));
                    ctx.scatter(data.as_deref(), root).unwrap()
                });
                let t2 = topo.clone();
                let (hier, report) = run_ranks_on(&topo, move |c| {
                    let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
                    let data = (c.rank() == root).then(|| rank_chunk(7, len));
                    ctx.scatter(data.as_deref(), root).unwrap()
                });
                for (rank, (h, f)) in hier.iter().zip(&flat).enumerate() {
                    assert_eq!(
                        bits(h),
                        bits(f),
                        "topo {topo:?} root {root} len {len} rank {rank}"
                    );
                }
                for &(a, b) in &report.inter_pairs {
                    assert!(topo.is_leader(a) && topo.is_leader(b), "{topo:?} root {root}");
                }
            }
        }
    }
}

/// Hier allreduce's inter tier IS the flat ZCCL allreduce over the leader
/// group: running flat ZCCL on a leaders-only fabric fed the node-reduced
/// inputs must reproduce the hierarchical result bit for bit.
#[test]
fn hier_allreduce_bit_identical_to_leader_tier_reference() {
    let len = 2500;
    for topo in shapes() {
        let n = topo.ranks();
        for op in [ReduceOp::Sum, ReduceOp::Max] {
            let t2 = topo.clone();
            let (hier, _) = run_ranks_on(&topo, move |c| {
                let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
                let input = rank_chunk(ctx.rank(), len);
                ctx.allreduce(&input, op).unwrap()
            });
            // Node-reduced inputs, folded in ascending member order — the
            // same order the leader folds raw member partials.
            let nodes = topo.nodes();
            let node_sums: Vec<Vec<f32>> = (0..nodes)
                .map(|j| {
                    let members = topo.members(j);
                    let mut acc = rank_chunk(members[0], len);
                    for &r in &members[1..] {
                        op.fold(&mut acc, &rank_chunk(r, len));
                    }
                    acc
                })
                .collect();
            let reference = run_ranks(nodes, move |c| {
                let mut ctx = CollCtx::over(c, inter_mode());
                let me = ctx.rank();
                ctx.allreduce(&node_sums[me], op).unwrap()
            });
            for (rank, h) in hier.iter().enumerate() {
                assert_eq!(bits(h), bits(&reference[0]), "topo {topo:?} {op:?} rank {rank}");
            }
        }
    }
}

/// With one rank per node the hierarchy is the identity: hier == flat
/// ZCCL on the very same communicator, bit for bit.
#[test]
fn hier_allreduce_flat_topology_matches_flat_zccl() {
    let (n, len) = (5, 3000);
    let flat = run_ranks(n, move |c| {
        let mut ctx = CollCtx::over(c, inter_mode());
        let input = rank_chunk(ctx.rank(), len);
        ctx.allreduce(&input, ReduceOp::Sum).unwrap()
    });
    let topo = Topology::flat(n);
    let (hier, report) = run_ranks_on(&topo, move |c| {
        let mut ctx = CollCtx::over_nodes(c, hier_mode(), Topology::flat(5)).unwrap();
        let input = rank_chunk(ctx.rank(), len);
        ctx.allreduce(&input, ReduceOp::Sum).unwrap()
    });
    for (h, f) in hier.iter().zip(&flat) {
        assert_eq!(bits(h), bits(f));
    }
    // Every rank is a leader, so crossings are unrestricted — but the
    // ledger must have seen traffic (everything is inter-node here).
    assert!(report.tier.inter_bytes > 0);
    assert_eq!(report.tier.intra_bytes, 0);
}

/// A hierarchical mode without an installed topology degenerates to flat
/// ZCCL (Topology::flat default).
#[test]
fn hier_without_topology_degenerates_to_flat() {
    let (n, len) = (4, 1500);
    let flat = run_ranks(n, move |c| {
        let mut ctx = CollCtx::over(c, inter_mode());
        let input = rank_chunk(ctx.rank(), len);
        ctx.allreduce(&input, ReduceOp::Sum).unwrap()
    });
    let hier = run_ranks(n, move |c| {
        let mut ctx = CollCtx::over(c, hier_mode());
        let input = rank_chunk(ctx.rank(), len);
        ctx.allreduce(&input, ReduceOp::Sum).unwrap()
    });
    for (h, f) in hier.iter().zip(&flat) {
        assert_eq!(bits(h), bits(f));
    }
}

/// Accuracy: the hierarchical sum stays inside the compressed-chain error
/// envelope of the LEADER ring (L hops), not the full rank count — the
/// intra tier is exact. Avg finishes with the total rank count.
#[test]
fn hier_allreduce_error_envelope_and_avg() {
    let topo = Topology::blocked(4, 4);
    let (n, len) = (topo.ranks(), 4096);
    for op in [ReduceOp::Sum, ReduceOp::Avg] {
        let t2 = topo.clone();
        let (out, _) = run_ranks_on(&topo, move |c| {
            let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
            let input = rank_chunk(ctx.rank(), len);
            ctx.allreduce(&input, op).unwrap()
        });
        let mut exact = rank_chunk(0, len);
        for r in 1..n {
            op.fold(&mut exact, &rank_chunk(r, len));
        }
        op.finish(&mut exact, n);
        // The reduce-scatter chain over L = 4 leaders injects at most
        // (L-1)·ê into the (pre-finish) partial — scaled by 1/n for Avg —
        // and the allgather hop compresses the finished chunk once more
        // at full ê.
        let scale = if op == ReduceOp::Avg { 1.0 / n as f64 } else { 1.0 };
        let tol = (topo.nodes() as f64 - 1.0) * EB * scale + EB * 1.01 + 1e-5;
        for o in &out {
            assert_eq!(o.len(), len);
            for (a, b) in o.iter().zip(&exact) {
                assert!(((a - b).abs() as f64) <= tol, "{op:?}: {a} vs {b} tol {tol}");
            }
        }
        for o in &out[1..] {
            assert_eq!(bits(o), bits(&out[0]), "all ranks identical ({op:?})");
        }
    }
}

/// The ISSUE acceptance: over a 4-node × 4-rank fabric, each node's data
/// is compressed exactly once per frame, by its leader; followers never
/// touch the codec; every slow-tier crossing is leader↔leader.
#[test]
fn acceptance_4x4_compress_once_per_node_leaders_only() {
    let topo = Topology::blocked(4, 4);
    let nodes = topo.nodes();
    let len = 4096;

    // Allreduce: each leader compresses L frames (L-1 reduce-scatter
    // rounds + its allgather chunk), followers none, and nobody decodes
    // anything off the fast tier except leaders.
    let t2 = topo.clone();
    let (out, report) = run_ranks_on(&topo, move |c| {
        let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
        let input = rank_chunk(ctx.rank(), len);
        let r = ctx.allreduce(&input, ReduceOp::Sum).unwrap();
        let pool = ctx.pool_stats();
        (r, ctx.compress_calls(), pool.placement_decodes + pool.staged_decodes)
    });
    for (rank, (_, compresses, decodes)) in out.iter().enumerate() {
        if topo.is_leader(rank) {
            assert_eq!(
                *compresses,
                nodes as u64,
                "leader {rank} compresses one frame per inter-tier hop"
            );
            assert!(*decodes > 0, "leader {rank} decodes");
        } else {
            assert_eq!(*compresses, 0, "follower {rank} must never compress");
            assert_eq!(*decodes, 0, "follower {rank} must never decompress");
        }
    }
    assert!(report.tier.inter_bytes > 0, "leaders exchanged compressed frames");
    assert!(report.tier.intra_bytes > 0, "members exchanged raw windows");
    assert!(!report.inter_pairs.is_empty());
    for &(a, b) in &report.inter_pairs {
        assert!(
            topo.is_leader(a) && topo.is_leader(b),
            "slow tier crossed by non-leaders: {a} -> {b}"
        );
    }
    for o in &out[1..] {
        assert_eq!(bits(&o.0), bits(&out.first().unwrap().0), "MPI semantics");
    }

    // Allgather: exactly one compression per member chunk, all at the
    // leader — "compress once per node" in its purest form.
    let t3 = topo.clone();
    let (ag, report) = run_ranks_on(&topo, move |c| {
        let mut ctx = CollCtx::over_nodes(c, hier_mode(), t3.clone()).unwrap();
        let mine = rank_chunk(ctx.rank(), 700);
        ctx.allgather(&mine).unwrap();
        ctx.compress_calls()
    });
    for (rank, compresses) in ag.iter().enumerate() {
        let want = if topo.is_leader(rank) {
            topo.members(topo.node_of(rank)).len() as u64
        } else {
            0
        };
        assert_eq!(*compresses, want, "rank {rank}: one compression per node chunk");
    }
    for &(a, b) in &report.inter_pairs {
        assert!(topo.is_leader(a) && topo.is_leader(b));
    }
}

/// Warm hierarchical allreduce performs zero scratch-pool growth and
/// zero packet-pool allocations — the satellite regression mirroring the
/// flat warm-path tests.
#[test]
fn warm_hier_allreduce_is_allocation_free() {
    let topo = Topology::blocked(2, 2);
    let len = 5000;
    let t2 = topo.clone();
    let (ok, _) = run_ranks_on(&topo, move |c| {
        let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
        let input = rank_chunk(ctx.rank(), len);
        let mut out = Vec::new();

        // Deterministically pre-warm the fabric-shared packet pool past
        // any possible concurrent demand, so the post-warm-up counter
        // cannot depend on thread interleaving (same pattern as the flat
        // placement-decode regression).
        let warmed: Vec<Vec<u8>> = (0..16)
            .map(|_| {
                let mut b = ctx.transport().lease();
                b.reserve_exact(64 << 10);
                b
            })
            .collect();
        ctx.barrier().unwrap();
        for b in warmed {
            ctx.transport().recycle(b);
        }

        ctx.allreduce_into(&input, ReduceOp::Sum, &mut out).unwrap();
        ctx.allreduce_into(&input, ReduceOp::Sum, &mut out).unwrap();
        ctx.barrier().unwrap();
        let warm = ctx.pool_stats();
        let warm_packets = ctx.packet_stats().allocated;
        let warm_builds = ctx.codec_builds();

        for _ in 0..3 {
            ctx.allreduce_into(&input, ReduceOp::Sum, &mut out).unwrap();
        }
        ctx.barrier().unwrap();
        let after = ctx.pool_stats();
        assert_eq!(
            after.byte_buffers_created, warm.byte_buffers_created,
            "warm hier allreduce must not create byte buffers"
        );
        assert_eq!(
            after.f32_buffers_created, warm.f32_buffers_created,
            "warm hier allreduce must not create f32 buffers"
        );
        assert_eq!(
            ctx.packet_stats().allocated,
            warm_packets,
            "warm hier allreduce must lease every wire buffer from the pool"
        );
        assert_eq!(ctx.codec_builds(), warm_builds, "no per-iteration codec builds");
        true
    });
    assert!(ok.into_iter().all(|x| x));
}

/// Hier gather and alltoall are bit-identical to flat ZCCL on every node
/// shape: the leader compresses each member chunk at the flat per-rank
/// frame boundaries (the intra raw hop is exact), so the same frames
/// cross the wire and the same bytes decode at the destination. Unequal
/// chunk lengths — including an empty contribution — are swept.
#[test]
fn hier_gather_and_alltoall_bit_identical_to_flat_zccl() {
    for topo in shapes() {
        let n = topo.ranks();
        let gather_len = |r: usize| if r == 1 { 0 } else { 150 + 13 * r };
        let a2a_len = move |r: usize| 40 * n + 7 * r;
        for root in [0, 1 % n, n - 1] {
            let flat = run_ranks(n, move |c| {
                let mut ctx = CollCtx::over(c, inter_mode());
                let g = ctx.gather(&rank_chunk(ctx.rank(), gather_len(ctx.rank())), root).unwrap();
                let a2a = ctx.alltoall(&rank_chunk(ctx.rank(), a2a_len(ctx.rank()))).unwrap();
                (g, a2a)
            });
            let t2 = topo.clone();
            let (hier, report) = run_ranks_on(&topo, move |c| {
                let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
                let g = ctx.gather(&rank_chunk(ctx.rank(), gather_len(ctx.rank())), root).unwrap();
                let a2a = ctx.alltoall(&rank_chunk(ctx.rank(), a2a_len(ctx.rank()))).unwrap();
                (g, a2a)
            });
            for (rank, (h, f)) in hier.iter().zip(&flat).enumerate() {
                assert_eq!(
                    h.0.as_deref().map(bits),
                    f.0.as_deref().map(bits),
                    "gather, topo {topo:?} root {root} rank {rank}"
                );
                assert_eq!(bits(&h.1), bits(&f.1), "alltoall, topo {topo:?} rank {rank}");
            }
            for &(a, b) in &report.inter_pairs {
                assert!(
                    topo.is_leader(a) && topo.is_leader(b),
                    "slow tier crossed by non-leaders {a}->{b} in {topo:?}"
                );
            }
        }
    }
}

/// Hier reduce-scatter's inter tier IS flat ZCCL reduce-scatter over the
/// leader group on the node partials: reconstructing the reduced vector
/// from a leaders-only reference run and slicing it at the n-way
/// ownership boundaries must reproduce every hier rank's owned chunk bit
/// for bit.
#[test]
fn hier_reduce_scatter_matches_leader_tier_reference() {
    let len = 2200;
    for topo in shapes() {
        let n = topo.ranks();
        let t2 = topo.clone();
        let (hier, report) = run_ranks_on(&topo, move |c| {
            let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
            let input = rank_chunk(ctx.rank(), len);
            ctx.reduce_scatter(&input, ReduceOp::Sum).unwrap()
        });
        let nodes = topo.nodes();
        let node_partials: Vec<Vec<f32>> = (0..nodes)
            .map(|j| {
                let members = topo.members(j);
                let mut acc = rank_chunk(members[0], len);
                for &r in &members[1..] {
                    ReduceOp::Sum.fold(&mut acc, &rank_chunk(r, len));
                }
                acc
            })
            .collect();
        let reference = run_ranks(nodes, move |c| {
            let mut ctx = CollCtx::over(c, inter_mode());
            let me = ctx.rank();
            ctx.reduce_scatter(&node_partials[me], ReduceOp::Sum).unwrap()
        });
        let mut full = vec![0.0f32; len];
        for (range, vals) in &reference {
            full[range.clone()].copy_from_slice(vals);
        }
        let ranges = chunk_ranges(len, n);
        for (me, (range, vals)) in hier.iter().enumerate() {
            let own = ranges[(me + 1) % n].clone();
            assert_eq!(*range, own, "ownership range, topo {topo:?} rank {me}");
            assert_eq!(bits(vals), bits(&full[own]), "topo {topo:?} rank {me}");
        }
        for &(a, b) in &report.inter_pairs {
            assert!(topo.is_leader(a) && topo.is_leader(b), "{topo:?}: {a}->{b}");
        }
    }
}

/// Hier reduce's inter tier IS flat ZCCL reduce over the leader group
/// toward the root's leader: a leaders-only reference run on the node
/// partials reproduces the hier root's result bit for bit (Sum and Max
/// finish as identity, so the divisor difference cannot surface here).
#[test]
fn hier_reduce_matches_leader_tier_reference() {
    let len = 1800;
    for topo in shapes() {
        let n = topo.ranks();
        for op in [ReduceOp::Sum, ReduceOp::Max] {
            for root in [0, n - 1] {
                let t2 = topo.clone();
                let (hier, report) = run_ranks_on(&topo, move |c| {
                    let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
                    let input = rank_chunk(ctx.rank(), len);
                    ctx.reduce(&input, op, root).unwrap()
                });
                let nodes = topo.nodes();
                let root_node = topo.node_of(root);
                let node_partials: Vec<Vec<f32>> = (0..nodes)
                    .map(|j| {
                        let members = topo.members(j);
                        let mut acc = rank_chunk(members[0], len);
                        for &r in &members[1..] {
                            op.fold(&mut acc, &rank_chunk(r, len));
                        }
                        acc
                    })
                    .collect();
                let reference = run_ranks(nodes, move |c| {
                    let mut ctx = CollCtx::over(c, inter_mode());
                    let me = ctx.rank();
                    ctx.reduce(&node_partials[me], op, root_node).unwrap()
                });
                let want = reference[root_node].as_ref().expect("reference root holds result");
                for (rank, h) in hier.iter().enumerate() {
                    if rank == root {
                        let h = h.as_ref().expect("hier root holds result");
                        assert_eq!(bits(h), bits(want), "topo {topo:?} {op:?} root {root}");
                    } else {
                        assert!(h.is_none(), "non-root {rank} returned a result");
                    }
                }
                for &(a, b) in &report.inter_pairs {
                    assert!(topo.is_leader(a) && topo.is_leader(b), "{topo:?}: {a}->{b}");
                }
            }
        }
    }
}

/// Hier Avg finishes with the TOTAL rank count, not the leader count —
/// the node partials already hold every member's contribution.
#[test]
fn hier_reduce_avg_divides_by_total_ranks() {
    let topo = Topology::blocked(2, 3);
    let (n, len) = (topo.ranks(), 1024);
    let t2 = topo.clone();
    let (out, _) = run_ranks_on(&topo, move |c| {
        let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
        let input = rank_chunk(ctx.rank(), len);
        ctx.reduce(&input, ReduceOp::Avg, 0).unwrap()
    });
    let mut exact = rank_chunk(0, len);
    for r in 1..n {
        ReduceOp::Avg.fold(&mut exact, &rank_chunk(r, len));
    }
    ReduceOp::Avg.finish(&mut exact, n);
    let got = out[0].as_ref().unwrap();
    // One compressed up-link per leader-tree edge; generous envelope.
    let tol = (topo.nodes() as f64) * EB + 1e-5;
    for (a, b) in got.iter().zip(&exact) {
        assert!(((a - b).abs() as f64) <= tol, "{a} vs {b} (tol {tol})");
    }
}

/// The staged (version-2) adaptive codec composes with the hierarchy:
/// staged hier gather / alltoall / bcast stay bit-identical to staged
/// flat ZCCL — the leaders forward staged frames verbatim exactly as they
/// forward version-1 frames.
#[test]
fn staged_codec_hier_collectives_bit_identical_to_flat_staged() {
    let topo = Topology::grouped(&[3, 1, 2]).unwrap();
    let n = topo.ranks();
    let len = 2600;
    let flat = run_ranks(n, move |c| {
        let mut ctx = CollCtx::over(c, inter_mode().with_staged(true));
        let data = (c.rank() == 1).then(|| rank_chunk(11, len));
        let b = ctx.bcast(data.as_deref(), 1).unwrap();
        let g = ctx.gather(&rank_chunk(ctx.rank(), 300), n - 1).unwrap();
        let a2a = ctx.alltoall(&rank_chunk(ctx.rank(), 40 * n)).unwrap();
        (b, g, a2a)
    });
    let t2 = topo.clone();
    let (hier, _) = run_ranks_on(&topo, move |c| {
        let mut ctx = CollCtx::over_nodes(c, hier_mode().with_staged(true), t2.clone()).unwrap();
        let data = (c.rank() == 1).then(|| rank_chunk(11, len));
        let b = ctx.bcast(data.as_deref(), 1).unwrap();
        let g = ctx.gather(&rank_chunk(ctx.rank(), 300), n - 1).unwrap();
        let a2a = ctx.alltoall(&rank_chunk(ctx.rank(), 40 * n)).unwrap();
        (b, g, a2a)
    });
    for (rank, (h, f)) in hier.iter().zip(&flat).enumerate() {
        assert_eq!(bits(&h.0), bits(&f.0), "staged bcast, rank {rank}");
        let (hg, fg) = (h.1.as_deref().map(bits), f.1.as_deref().map(bits));
        assert_eq!(hg, fg, "staged gather, rank {rank}");
        assert_eq!(bits(&h.2), bits(&f.2), "staged alltoall, rank {rank}");
    }
}

/// A compressed intra tier keeps the allreduce inside a widened (one
/// extra `D∘C` per intra hop) error envelope, moves the up-hop
/// compression onto the followers, and leaves the message graph — tier
/// split included — untouched.
#[test]
fn compressed_intra_tier_bounded_and_counted() {
    let topo = Topology::blocked(2, 3);
    let (n, len) = (topo.ranks(), 4096);
    let t2 = topo.clone();
    let (out, report) = run_ranks_on(&topo, move |c| {
        let mut ctx = CollCtx::over_nodes(c, hier_mode(), t2.clone()).unwrap();
        ctx.set_intra_mode(inter_mode()).unwrap();
        let input = rank_chunk(ctx.rank(), len);
        let r = ctx.allreduce(&input, ReduceOp::Sum).unwrap();
        (r, ctx.intra_compress_calls())
    });
    let mut exact = rank_chunk(0, len);
    for r in 1..n {
        ReduceOp::Sum.fold(&mut exact, &rank_chunk(r, len));
    }
    // Inter-tier chain (leader ring + allgather hop) plus one D∘C per
    // intra hop: follower partial up, result down the member binomial.
    let tol = ((topo.nodes() + n + 2) as f64) * EB + 1e-4;
    for (o, _) in &out {
        assert_eq!(o.len(), len);
        for (a, b) in o.iter().zip(&exact) {
            assert!(((a - b).abs() as f64) <= tol, "{a} vs {b} (tol {tol})");
        }
    }
    for (rank, (_, intra_calls)) in out.iter().enumerate() {
        assert!(*intra_calls > 0, "rank {rank} never exercised the intra codec");
    }
    // The tier split is unchanged: compressed intra traffic is still
    // intra, and the slow tier stays leader↔leader.
    assert!(report.tier.inter_bytes > 0);
    assert!(report.tier.intra_bytes > 0);
    for &(a, b) in &report.inter_pairs {
        assert!(topo.is_leader(a) && topo.is_leader(b));
    }
}

#[test]
fn topology_and_tier_mode_validation() {
    let n = 3;
    let results = run_ranks(n, move |c| {
        let mut ctx = CollCtx::over(c, hier_mode());
        // Wrong rank count is rejected.
        let bad = ctx.set_topology(Topology::flat(7));
        // Right rank count installs.
        let good = ctx.set_topology(Topology::grouped(&[2, 1]).unwrap());
        // Compressed intra tier is accepted; nesting Algo::Hier is not.
        let good_intra = ctx.set_intra_mode(inter_mode());
        let bad_intra = ctx.set_intra_mode(hier_mode());
        let raw_intra = ctx.set_intra_mode(Mode::plain());
        // Keep the ranks in lockstep (no collective ran here).
        (bad.is_err(), good.is_ok(), good_intra.is_ok(), bad_intra.is_err(), raw_intra.is_ok())
    });
    for r in results {
        assert_eq!(r, (true, true, true, true, true));
    }
}

/// Tier-1 guard for the CI `zccl bench hier` step: the library driver
/// must emit JSON that parses and carries the flat-vs-hier walls, the
/// pipelined-vs-monolithic inter-leader rows (monolithic / picked /
/// fine-4k), a segment pick inside the calibrator's clamps, and the
/// intra-mode rows — with the raw fast tier at zero intra compressions
/// and the compressed one strictly above.
#[test]
fn bench_hier_json_contract() {
    let (tables, summary) = hier_bench(0.002);
    assert_eq!(tables.len(), 4, "real + pipeline + intra + sim tables");
    let parsed = Json::parse(&summary.to_string()).expect("BENCH_hier.json must parse");
    assert_eq!(parsed.get("bench").and_then(Json::as_str), Some("hier"));
    for key in ["flat_wall_s", "hier_wall_s", "hier_slow_tier_mb"] {
        assert!(parsed.get(key).and_then(Json::as_f64).unwrap() > 0.0, "{key} must be > 0");
    }
    let picked = parsed.get("picked_segment_bytes").and_then(Json::as_f64).unwrap();
    assert!(
        (MIN_SEGMENT_BYTES as f64..=MAX_SEGMENT_BYTES as f64).contains(&picked),
        "picked segment {picked} outside the calibrator clamps"
    );
    let pipeline = parsed.get("pipeline").and_then(Json::as_arr).expect("pipeline array");
    let labels: Vec<&str> =
        pipeline.iter().map(|r| r.get("segment").and_then(Json::as_str).unwrap()).collect();
    assert_eq!(labels, ["monolithic", "picked", "fine-4k"]);
    for row in pipeline {
        assert!(row.get("wall_s").and_then(Json::as_f64).unwrap() > 0.0);
    }
    let intra = parsed.get("intra").and_then(Json::as_arr).expect("intra array");
    assert_eq!(intra.len(), 2, "raw and compressed intra rows");
    for row in intra {
        let mode = row.get("intra").and_then(Json::as_str).unwrap();
        let calls = row.get("intra_compress_calls").and_then(Json::as_f64).unwrap();
        if mode == "raw" {
            assert_eq!(calls, 0.0, "raw fast tier must not touch the intra codec");
        } else {
            assert!(calls > 0.0, "compressed fast tier must count intra compressions");
        }
        assert!(row.get("inter_mb").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(row.get("intra_mb").and_then(Json::as_f64).unwrap() > 0.0);
    }
}
