//! Integration: the AOT bridge end to end — load HLO text produced by
//! `python/compile/aot.py`, compile on the PJRT CPU client, execute, and
//! check numerics against Rust-side oracles.
//!
//! Requires `make artifacts`; tests skip (with a loud message) if the
//! artifact directory is missing so `cargo test` works standalone.

use zccl::runtime::{literal_f32, literal_i32, literal_to_f32, Manifest, Runtime};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    if !Runtime::available() {
        eprintln!("SKIP: built without the 'pjrt' feature (PJRT runtime stubbed)");
        return None;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn lorenzo_kernel_artifact_matches_rust_quantizer() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let spec = manifest.artifact("lorenzo_quant").unwrap();
    let module = rt.compile(&dir, spec).unwrap();

    let n = spec.inputs[0].elements();
    let field = zccl::data::fields::Field::generate(zccl::data::fields::FieldKind::Cesm, n, 5);
    let x = literal_f32(&field.values, &spec.inputs[0].shape).unwrap();
    let out = module.run(&[x]).unwrap();
    assert_eq!(out.len(), 2, "kernel returns (xhat, bits)");

    let xhat = literal_to_f32(&out[0]).unwrap();
    assert_eq!(xhat.len(), n);
    // The kernel is the numeric core of fZ-light: xhat = 2eb*round(x/2eb)
    // with eb = 1e-3 baked in by aot.py.
    let eb = 1e-3f64;
    for (i, (a, b)) in field.values.iter().zip(&xhat).enumerate() {
        let err = (*a as f64 - *b as f64).abs();
        assert!(err <= eb * (1.0 + 1e-5) + 1e-7, "idx {i}: |{a}-{b}| = {err}");
    }
    // bits sanity: small non-negative code lengths.
    let bits = out[1].to_vec::<i32>().unwrap();
    assert_eq!(bits.len(), n / 32);
    assert!(bits.iter().all(|&b| (0..=40).contains(&b)));
}

#[test]
fn grad_step_descends_and_matches_eval_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let grad = rt.compile(&dir, manifest.artifact("grad_step").unwrap()).unwrap();
    let eval = rt.compile(&dir, manifest.artifact("eval_loss").unwrap()).unwrap();

    let params = manifest.load_params().unwrap();
    let cfg = manifest.config;
    // Synthetic "shift" task batch: y = x + 1 mod vocab.
    let mut rng = zccl::data::rng::Rng::new(3);
    let x: Vec<i32> =
        (0..cfg.batch * cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect();
    let y: Vec<i32> = x.iter().map(|&t| (t + 1) % cfg.vocab as i32).collect();

    let mut inputs: Vec<zccl::runtime::Literal> = params
        .iter()
        .map(|(_, shape, vals)| literal_f32(vals, shape).unwrap())
        .collect();
    inputs.push(literal_i32(&x, &[cfg.batch, cfg.seq]).unwrap());
    inputs.push(literal_i32(&y, &[cfg.batch, cfg.seq]).unwrap());

    let out = grad.run(&inputs).unwrap();
    assert_eq!(out.len(), params.len() + 1);
    let loss0 = literal_to_f32(&out[0]).unwrap()[0];
    assert!(loss0.is_finite() && loss0 > 0.0, "loss {loss0}");
    // Near-uniform initial loss ~ ln(vocab).
    assert!((loss0 - (cfg.vocab as f32).ln()).abs() < 1.0);

    // SGD step in Rust, then the loss on the same batch must drop.
    let lr = 0.5f32;
    let mut new_inputs: Vec<zccl::runtime::Literal> = Vec::with_capacity(inputs.len());
    for (i, (_, shape, vals)) in params.iter().enumerate() {
        let g = literal_to_f32(&out[i + 1]).unwrap();
        let updated: Vec<f32> = vals.iter().zip(&g).map(|(p, gi)| p - lr * gi).collect();
        new_inputs.push(literal_f32(&updated, shape).unwrap());
    }
    new_inputs.push(literal_i32(&x, &[cfg.batch, cfg.seq]).unwrap());
    new_inputs.push(literal_i32(&y, &[cfg.batch, cfg.seq]).unwrap());
    let out1 = eval.run(&new_inputs).unwrap();
    let loss1 = literal_to_f32(&out1[0]).unwrap()[0];
    assert!(loss1 < loss0, "sgd step must descend: {loss0} -> {loss1}");
}

#[test]
fn grad_step_zccl_close_to_plain() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let plain = rt.compile(&dir, manifest.artifact("grad_step").unwrap()).unwrap();
    let zccl = rt.compile(&dir, manifest.artifact("grad_step_zccl").unwrap()).unwrap();
    let params = manifest.load_params().unwrap();
    let cfg = manifest.config;
    let mut rng = zccl::data::rng::Rng::new(4);
    let x: Vec<i32> =
        (0..cfg.batch * cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect();
    let y: Vec<i32> = x.iter().map(|&t| (t + 1) % cfg.vocab as i32).collect();
    let mut inputs: Vec<zccl::runtime::Literal> = params
        .iter()
        .map(|(_, shape, vals)| literal_f32(vals, shape).unwrap())
        .collect();
    inputs.push(literal_i32(&x, &[cfg.batch, cfg.seq]).unwrap());
    inputs.push(literal_i32(&y, &[cfg.batch, cfg.seq]).unwrap());
    let a = plain.run(&inputs).unwrap();
    let b = zccl.run(&inputs).unwrap();
    // Same loss; gradients within the baked-in error bound.
    let la = literal_to_f32(&a[0]).unwrap()[0];
    let lb = literal_to_f32(&b[0]).unwrap()[0];
    assert!((la - lb).abs() < 1e-6);
    let eb = manifest.grad_eb as f32;
    for i in 1..a.len() {
        let ga = literal_to_f32(&a[i]).unwrap();
        let gb = literal_to_f32(&b[i]).unwrap();
        for (p, q) in ga.iter().zip(&gb) {
            assert!((p - q).abs() <= eb * 1.01 + 1e-7, "grad {i}: {p} vs {q}");
        }
    }
}
