//! Integration tests for the persistent `CollCtx` API:
//!
//! 1. A cross-codec property test: after a full collective round-trip the
//!    elementwise error respects the codec's error bound for **every
//!    error-bounded codec** (fZ-light, SZx, ZFP-ABS). `ZfpFixedRate` is
//!    exempt by design — fixed-rate coding does not bound the error,
//!    which is exactly the paper's criticism of fixed-rate baselines —
//!    and the exemption is itself asserted via `is_error_bounded()`.
//! 2. An allocation-reuse regression test: iterated `ctx.allreduce` calls
//!    on same-sized input perform zero pool growth and zero codec
//!    construction after the warm-up call.

use zccl::collectives::{run_ranks, CollCtx, Mode, ReduceOp};
use zccl::compress::{build, Compressor, CompressorKind, ErrorBound};
use zccl::data::fields::{Field, FieldKind};

const EB: f64 = 1e-3;

/// The codecs whose fixed-accuracy contract the collectives must carry
/// end to end.
const ERROR_BOUNDED: [CompressorKind; 3] =
    [CompressorKind::FzLight, CompressorKind::Szx, CompressorKind::ZfpAbs];

fn rank_input(rank: usize, len: usize) -> Vec<f32> {
    Field::generate(FieldKind::Hurricane, len, 9000 + rank as u64).values
}

#[test]
fn fixed_rate_codec_is_documented_as_exempt() {
    // ZfpFixedRate records the requested bound but does not honour it;
    // the trait exposes that so harnesses can exclude it — the property
    // tests below iterate ERROR_BOUNDED only.
    assert!(!build(CompressorKind::ZfpFixedRate).is_error_bounded());
    for kind in ERROR_BOUNDED {
        assert!(build(kind).is_error_bounded(), "{kind:?}");
    }
}

#[test]
fn allgather_roundtrip_respects_eb_for_every_error_bounded_codec() {
    // Data movement: each datum is compressed exactly once, so the
    // end-to-end elementwise error must stay within eb_abs itself.
    let (n, len) = (4usize, 3000usize);
    for kind in ERROR_BOUNDED {
        let mode = Mode::zccl(kind, ErrorBound::Abs(EB));
        let out = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let mine = rank_input(ctx.rank(), len);
            ctx.allgather(&mine).unwrap()
        });
        let want: Vec<f32> = (0..n).flat_map(|r| rank_input(r, len)).collect();
        for o in out {
            assert_eq!(o.len(), want.len(), "{kind:?} length");
            for (i, (a, b)) in o.iter().zip(&want).enumerate() {
                let err = (*a as f64 - *b as f64).abs();
                let tol = EB * 1.001 + (*b as f64).abs() * 1e-6 + 1e-6;
                assert!(err <= tol, "{kind:?} idx {i}: |{a} - {b}| = {err:.3e} > {tol:.3e}");
            }
        }
    }
}

#[test]
fn allreduce_roundtrip_respects_aggregated_eb_for_every_error_bounded_codec() {
    // Collective computation: the reduce-scatter chain re-compresses
    // updated partials, so the deterministic worst case is the aggregated
    // envelope (n-1)·eb for the chain plus one more eb for the allgather
    // stage — assert (n+1)·eb with the usual f32 slack.
    let (n, len) = (4usize, 3000usize);
    for kind in ERROR_BOUNDED {
        let mode = Mode::zccl(kind, ErrorBound::Abs(EB));
        let out = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let input = rank_input(ctx.rank(), len);
            ctx.allreduce(&input, ReduceOp::Sum).unwrap()
        });
        let mut want = rank_input(0, len);
        for r in 1..n {
            ReduceOp::Sum.fold(&mut want, &rank_input(r, len));
        }
        let tol = (n as f64 + 1.0) * EB * 1.01 + 1e-5;
        for o in out {
            assert_eq!(o.len(), len, "{kind:?} length");
            for (i, (a, b)) in o.iter().zip(&want).enumerate() {
                let err = (*a as f64 - *b as f64).abs();
                assert!(err <= tol, "{kind:?} idx {i}: |{a} - {b}| = {err:.3e} > {tol:.3e}");
            }
        }
    }
}

#[test]
fn iterated_allreduce_performs_zero_pool_growth_after_warmup() {
    let (n, len) = (4usize, 6000usize);
    let mode = Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(EB));
    let ok = run_ranks(n, move |c| {
        let mut ctx = CollCtx::over(c, mode);
        let input = rank_input(ctx.rank(), len);
        let mut out = Vec::new();

        // Warm-up call populates the pool and the destination buffer.
        ctx.allreduce_into(&input, ReduceOp::Sum, &mut out).unwrap();
        let warm = ctx.pool_stats();
        let builds = ctx.codec_builds();
        assert_eq!(builds, 1, "context must build its codec exactly once");
        assert!(warm.byte_buffers_created > 0, "pool must be exercised");
        assert!(warm.f32_buffers_created > 0, "pool must be exercised");

        // Same-sized iterations: the pool must serve everything from its
        // free lists — zero new buffers, a stable high-water mark, and no
        // codec construction.
        for _ in 0..3 {
            ctx.allreduce_into(&input, ReduceOp::Sum, &mut out).unwrap();
        }
        let after = ctx.pool_stats();
        assert_eq!(
            after.byte_buffers_created, warm.byte_buffers_created,
            "byte-buffer creations grew after warm-up"
        );
        assert_eq!(
            after.f32_buffers_created, warm.f32_buffers_created,
            "f32-buffer creations grew after warm-up"
        );
        assert_eq!(
            after.byte_capacity_hwm, warm.byte_capacity_hwm,
            "byte capacity high-water mark moved after warm-up"
        );
        assert_eq!(
            after.f32_capacity_hwm, warm.f32_capacity_hwm,
            "f32 capacity high-water mark moved after warm-up"
        );
        assert!(after.reuses > warm.reuses, "warm iterations must hit the free list");
        assert_eq!(ctx.codec_builds(), builds, "codec rebuilt after warm-up");
        true
    });
    assert!(ok.into_iter().all(|x| x));
}

#[test]
fn iterated_allreduce_matches_one_shot_results() {
    // Reusing pooled scratch must not change numerics: the 3rd iteration
    // equals the 1st bit for bit (deterministic codecs, same input).
    let (n, len) = (3usize, 2048usize);
    let mode = Mode::zccl(CompressorKind::Szx, ErrorBound::Abs(EB));
    let ok = run_ranks(n, move |c| {
        let mut ctx = CollCtx::over(c, mode);
        let input = rank_input(ctx.rank(), len);
        let first = ctx.allreduce(&input, ReduceOp::Sum).unwrap();
        let mut third = Vec::new();
        ctx.allreduce_into(&input, ReduceOp::Sum, &mut third).unwrap();
        ctx.allreduce_into(&input, ReduceOp::Sum, &mut third).unwrap();
        first == third
    });
    assert!(ok.into_iter().all(|x| x));
}

#[test]
fn into_roundtrip_through_ctx_for_all_four_codecs() {
    // Every codec — including the non-error-bounded fixed-rate baseline —
    // must survive a compress_into/decompress_into round-trip carried by
    // the collective layer (length-preserving; error bounds are asserted
    // separately above for the bounded codecs).
    let (n, len) = (3usize, 1500usize);
    for kind in CompressorKind::ALL {
        let mode = Mode::zccl(kind, ErrorBound::Abs(EB));
        let out = run_ranks(n, move |c| {
            let mut ctx = CollCtx::over(c, mode);
            let mine = rank_input(ctx.rank(), len);
            ctx.allgather(&mine).unwrap()
        });
        for o in &out {
            assert_eq!(o.len(), n * len, "{kind:?}: length must survive the round-trip");
        }
        for o in &out[1..] {
            assert_eq!(o, &out[0], "{kind:?}: all ranks must decode identically");
        }
    }
}
