//! Integration: the full ZCCL collective stack over the REAL TCP mesh
//! transport (multi-threaded here; `zccl launch` runs the same code
//! multi-process).

use std::net::{SocketAddr, TcpListener};
use std::thread;
use std::time::Duration;

use zccl::collectives::{allreduce, bcast, Communicator, Mode, ReduceOp};
use zccl::compress::{CompressorKind, ErrorBound};
use zccl::coordinator::Metrics;
use zccl::data::fields::{Field, FieldKind};
use zccl::transport::tcp::TcpTransport;

fn local_addrs(n: usize) -> Vec<SocketAddr> {
    let ls: Vec<TcpListener> =
        (0..n).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
    ls.iter().map(|l| l.local_addr().unwrap()).collect()
}

fn run_tcp<R: Send + 'static>(
    n: usize,
    f: impl Fn(&mut Communicator) -> R + Send + Sync + Clone + 'static,
) -> Vec<R> {
    let addrs = local_addrs(n);
    let joins: Vec<_> = (0..n)
        .map(|rank| {
            let addrs = addrs.clone();
            let f = f.clone();
            thread::spawn(move || {
                let mut t =
                    TcpTransport::connect(rank, &addrs, Duration::from_secs(20)).unwrap();
                let mut comm = Communicator::new(&mut t);
                f(&mut comm)
            })
        })
        .collect();
    joins.into_iter().map(|j| j.join().unwrap()).collect()
}

#[test]
fn zccl_allreduce_over_tcp_matches_serial() {
    let n = 3;
    let len = 40_000;
    let eb = 1e-3f64;
    let out = run_tcp(n, move |comm| {
        let f = Field::generate(FieldKind::Hurricane, len, 70 + comm.rank() as u64);
        let mut m = Metrics::default();
        allreduce(
            comm,
            &f.values,
            ReduceOp::Sum,
            &Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(eb)),
            &mut m,
        )
        .unwrap()
    });
    let mut exact = Field::generate(FieldKind::Hurricane, len, 70).values;
    for r in 1..n {
        let f = Field::generate(FieldKind::Hurricane, len, 70 + r as u64);
        for (a, v) in exact.iter_mut().zip(&f.values) {
            *a += v;
        }
    }
    let tol = (n as f64 + 1.0) * eb * 1.01 + 1e-5;
    for o in &out {
        for (a, b) in o.iter().zip(&exact) {
            assert!(((a - b).abs() as f64) <= tol, "{a} vs {b}");
        }
    }
    // Identical output on every rank.
    for o in &out[1..] {
        assert_eq!(o, &out[0]);
    }
}

#[test]
fn bcast_over_tcp_with_segmented_pipeline() {
    let n = 4;
    let len = 30_000;
    let out = run_tcp(n, move |comm| {
        let data = (comm.rank() == 1).then(|| Field::generate(FieldKind::Rtm, len, 9).values);
        let mut m = Metrics::default();
        bcast(
            comm,
            data.as_deref(),
            1,
            &Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-3)),
            &mut m,
        )
        .unwrap()
    });
    let want = Field::generate(FieldKind::Rtm, len, 9).values;
    for o in out {
        assert_eq!(o.len(), want.len());
        for (a, b) in o.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-3 * 1.001 + 1e-6);
        }
    }
}
