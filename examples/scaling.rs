//! Node-count scaling (Fig. 13) on the calibrated virtual-time simulator,
//! plus a real-transport cross-check at small rank counts.
//!
//! ```sh
//! cargo run --release --example scaling
//! ```

use zccl::collectives::Algo;
use zccl::compress::{CompressorKind, ErrorBound};
use zccl::data::fields::FieldKind;
use zccl::sim::calibrate::sample_ratio;
use zccl::sim::collectives::{sim_allreduce, SimParams};
use zccl::sim::CostModel;

fn main() -> zccl::Result<()> {
    let cm = CostModel::paper_broadwell();
    let ratio = sample_ratio(
        CompressorKind::FzLight,
        FieldKind::Rtm,
        ErrorBound::Rel(1e-4),
        1 << 18,
        17,
    );
    println!("Allreduce of 678 MB (full RTM dataset), fZ-light ratio {ratio:.1}\n");
    println!(
        "{:>6} {:>10} {:>10} {:>10} {:>12} {:>12}",
        "nodes", "MPI s", "ZCCL-1T s", "ZCCL-MT s", "speedup 1T", "speedup MT"
    );
    for n in [2usize, 4, 8, 16, 32, 64, 128] {
        let base = SimParams {
            n,
            bytes: 678e6,
            algo: Algo::Plain,
            kind: CompressorKind::FzLight,
            multithread: false,
            ratio,
        };
        let mpi = sim_allreduce(&base, &cm);
        let st = sim_allreduce(&SimParams { algo: Algo::Zccl, ..base }, &cm);
        let mt = sim_allreduce(
            &SimParams { algo: Algo::Zccl, multithread: true, ..base },
            &cm,
        );
        println!(
            "{:>6} {:>10.3} {:>10.3} {:>10.3} {:>12.2} {:>12.2}",
            n,
            mpi.makespan_s,
            st.makespan_s,
            mt.makespan_s,
            mpi.makespan_s / st.makespan_s,
            mpi.makespan_s / mt.makespan_s
        );
    }
    println!(
        "\ncost model: effective link {:.1} GB/s, fZ-light {:.1}/{:.1} GB/s ST/MT \
         (paper Tables 1-2); see `zccl bench crosscheck` for sim-vs-real validation",
        cm.link_bps / 1e9,
        cm.fzlight.comp_st / 1e9,
        cm.fzlight.comp_mt / 1e9
    );
    Ok(())
}
