//! END-TO-END VALIDATION (DESIGN.md §6): data-parallel training of the
//! AOT-compiled transformer with ZCCL compressed-gradient allreduce.
//!
//! All three layers compose here: the L1 Pallas kernel and L2 JAX model
//! were lowered once by `make artifacts`; each Rust worker executes
//! `grad_step` through the PJRT runtime; the L3 collective averages the
//! gradients with error-bounded compression on the wire. The loss curves
//! for plain vs Z-Allreduce training land in `results/ddp_loss.csv` — the
//! paper's accuracy claim transplanted to the dist-train domain.
//!
//! ```sh
//! make artifacts && cargo run --release --example ddp_train [workers] [steps]
//! ```

use zccl::apps::ddp::{train, DdpConfig};
use zccl::collectives::Mode;
use zccl::compress::{CompressorKind, ErrorBound};

fn main() -> zccl::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let workers: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }

    std::fs::create_dir_all("results")?;
    let runs: Vec<(&str, Mode)> = vec![
        ("plain", Mode::plain()),
        ("zccl", Mode::zccl(CompressorKind::FzLight, ErrorBound::Abs(1e-4))),
    ];
    let mut curves: Vec<(String, Vec<(usize, f32, f64)>)> = Vec::new();
    for (label, mode) in runs {
        println!("== {label} gradient allreduce: {workers} workers x {steps} steps ==");
        let cfg = DdpConfig::new(dir, workers, steps, mode);
        let t0 = std::time::Instant::now();
        let report = train(&cfg)?;
        let total = t0.elapsed().as_secs_f64();
        let first = report.steps.first().map(|s| s.loss).unwrap_or(0.0);
        let last = report.steps.last().map(|s| s.loss).unwrap_or(0.0);
        let ar: f64 =
            report.steps.iter().map(|s| s.allreduce_s).sum::<f64>() / steps.max(1) as f64;
        println!(
            "   loss {first:.4} -> {last:.4} | {total:.1}s total, \
             {:.1} ms/step allreduce | sent {:.1} MB",
            ar * 1e3,
            report.metrics.bytes_sent as f64 / 1e6
        );
        curves.push((
            label.to_string(),
            report.steps.iter().map(|s| (s.step, s.loss, s.allreduce_s)).collect(),
        ));
    }

    // Loss curves side by side.
    let mut csv = String::from("step,loss_plain,loss_zccl,allreduce_s_plain,allreduce_s_zccl\n");
    for i in 0..curves[0].1.len() {
        let (s, lp, ap) = curves[0].1[i];
        let (_, lz, az) = curves[1].1[i];
        csv.push_str(&format!("{s},{lp:.5},{lz:.5},{ap:.6},{az:.6}\n"));
    }
    std::fs::write("results/ddp_loss.csv", csv)?;
    println!("\nloss curves -> results/ddp_loss.csv");

    // The accuracy claim: compressed-gradient training must track the
    // exact curve closely.
    let last_plain = curves[0].1.last().unwrap().1;
    let last_zccl = curves[1].1.last().unwrap().1;
    println!(
        "final loss: plain {last_plain:.4} vs zccl {last_zccl:.4} \
         (delta {:.2e})",
        (last_plain - last_zccl).abs()
    );
    Ok(())
}
