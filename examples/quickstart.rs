//! Quickstart: compress a scientific field with fZ-light, then run the
//! same data through a plain vs ZCCL Allreduce across four in-process
//! ranks — driven by the persistent [`CollCtx`] API — and compare time,
//! traffic and accuracy.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use zccl::collectives::{run_ranks, run_ranks_on, CollCtx, Mode, ReduceOp};
use zccl::compress::{stats::quality, Compressor, CompressorKind, ErrorBound, FzLight};
use zccl::data::fields::{Field, FieldKind};
use zccl::topology::Topology;

fn main() -> zccl::Result<()> {
    // --- 1. Error-bounded compression in three lines. -------------------
    let field = Field::generate(FieldKind::Hurricane, 1 << 20, 7);
    let eb = ErrorBound::Rel(1e-4);
    let frame = FzLight::default().compress(&field.values, eb)?;
    let restored = FzLight::default().decompress(&frame.bytes)?;
    let q = quality(&field.values, &restored);
    println!(
        "fZ-light on {} ({} MB): ratio {:.1}x, constant blocks {:.1}%, \
         max err {:.2e} (bound {:.2e}), PSNR {:.1} dB",
        field.kind.name(),
        field.values.len() * 4 / (1 << 20),
        frame.stats.ratio(),
        frame.stats.constant_fraction() * 100.0,
        q.max_err,
        eb.resolve(&field.values),
        q.psnr
    );

    // --- 2. The same compressor inside a collective, via CollCtx. --------
    // The context owns the codec (built once), a scratch-buffer pool and
    // the metrics sink; iterated calls reuse everything. The old free
    // functions (`zccl::collectives::allreduce(...)`) still exist as
    // compatibility shims over a transient context.
    let n = 4;
    let iters = 3;
    for (label, mode) in [
        ("plain MPI-style", Mode::plain()),
        ("Z-Allreduce (ZCCL)", Mode::zccl(CompressorKind::FzLight, eb)),
    ] {
        let out = run_ranks(n, move |comm| {
            let mut ctx = CollCtx::over(comm, mode);
            let f = Field::generate(FieldKind::Hurricane, 1 << 20, 7 + ctx.rank() as u64);
            let mut result = Vec::new();
            let t0 = std::time::Instant::now();
            for _ in 0..iters {
                // `_into` + the pools: warm iterations don't allocate —
                // wire buffers arrive by `recv_into` swap from the
                // transport's packet pool and frames decode straight
                // into their final windows (placement decode).
                ctx.allreduce_into(&f.values, ReduceOp::Sum, &mut result).unwrap();
            }
            let wall = t0.elapsed().as_secs_f64() / iters as f64;
            (wall, ctx.take_metrics(), ctx.pool_stats(), ctx.packet_stats())
        });
        let wall = out.iter().map(|x| x.0).fold(0.0, f64::max);
        let sent: u64 = out.iter().map(|x| x.1.bytes_sent).sum();
        let pool = out[0].2;
        let packets = out[0].3;
        println!(
            "{label:20} {n} ranks x {iters} iters: {:.3}s/iter, {:.1} MB on the wire, \
             {} scratch buffers, {} wire buffers (fabric), {} placement / {} staged decodes",
            wall,
            sent as f64 / 1e6,
            pool.byte_buffers_created + pool.f32_buffers_created,
            packets.allocated,
            pool.placement_decodes,
            pool.staged_decodes
        );
    }
    // --- 3. Hierarchical (topology-aware) collectives. -------------------
    // Real clusters have cheap intra-node links and an expensive network.
    // `Algo::Hier` consumes a rank→node Topology: members exchange raw
    // f32 over the fast tier, only the node LEADERS compress, and
    // compressed frames cross the slow tier strictly leader↔leader. The
    // node-partitioned memchan fabric classifies every message so the
    // tier split is observable.
    let topo = Topology::blocked(2, 2); // 2 nodes x 2 ranks
    let t2 = topo.clone();
    let (out, report) = run_ranks_on(&topo, move |comm| {
        let mode = Mode::hier(CompressorKind::FzLight, ErrorBound::Rel(1e-4));
        let mut ctx = CollCtx::over_nodes(comm, mode, t2.clone()).unwrap();
        let f = Field::generate(FieldKind::Hurricane, 1 << 20, 7 + ctx.rank() as u64);
        let mut result = Vec::new();
        ctx.allreduce_into(&f.values, ReduceOp::Sum, &mut result).unwrap();
        ctx.compress_calls()
    });
    println!(
        "hierarchical allreduce  {} ranks on {} nodes: {:.1} MB crossed the slow tier \
         ({:.1} MB stayed on-node); compress calls per rank: {:?} (leaders only)",
        topo.ranks(),
        topo.nodes(),
        report.tier.inter_bytes as f64 / 1e6,
        report.tier.intra_bytes as f64 / 1e6,
        out
    );
    println!(
        "(in-process transport: the wire-volume reduction is the point;\n \
         run `zccl bench fig12` for the cluster-scale timing model and\n \
         `zccl bench hier` for the flat-vs-hierarchical comparison)"
    );
    Ok(())
}
