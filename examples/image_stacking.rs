//! The paper's §4.6 use case: image stacking (reverse-time-migration
//! style) via Allreduce, across all collective modes, with accuracy
//! verification and PGM dumps.
//!
//! ```sh
//! cargo run --release --example image_stacking [ranks] [rows] [cols]
//! ```

use zccl::apps::{image_stacking, visualize};
use zccl::collectives::Mode;
use zccl::compress::{CompressorKind, ErrorBound};

fn main() -> zccl::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let ranks: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(8);
    let rows: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let cols: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(320);
    let images = 3;
    let eb = ErrorBound::Rel(1e-4);

    std::fs::create_dir_all("results")?;
    println!("stacking {images} images/rank x {ranks} ranks at {rows}x{cols}…\n");
    println!(
        "{:22} {:>8} {:>10} {:>10} {:>9} {:>9}",
        "solution", "wall s", "PSNR dB", "NRMSE", "comp %", "comm %"
    );
    let mut first = true;
    for (label, mode) in [
        ("MPI (plain)", Mode::plain()),
        ("CPRP2P", Mode::cprp2p(CompressorKind::FzLight, eb)),
        ("C-Coll (SZx)", Mode::ccoll(eb)),
        ("ZCCL 1-thread", Mode::zccl(CompressorKind::FzLight, eb)),
        ("ZCCL multi-thread", Mode::zccl(CompressorKind::FzLight, eb).with_multithread(true)),
    ] {
        let r = image_stacking::run(ranks, images, rows, cols, mode, 77)?;
        let (c, comm, _, _) = r.metrics.breakdown_pct();
        println!(
            "{label:22} {:>8.3} {:>10.1} {:>10.2e} {:>9.1} {:>9.1}",
            r.wall_s, r.quality.psnr, r.quality.nrmse, c, comm
        );
        if first {
            visualize::write_pgm("results/stack-exact.pgm", &r.image, rows, cols)?;
            first = false;
        }
        if label.starts_with("ZCCL 1") {
            visualize::write_pgm("results/stack-zccl.pgm", &r.image, rows, cols)?;
        }
    }
    println!("\nPGMs written to results/stack-*.pgm (visually identical, per Fig. 16)");
    Ok(())
}
