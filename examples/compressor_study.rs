//! The §3.3 compressor study (Tables 1–4, Figs. 5–7): fZ-light vs SZx on
//! all four synthetic application datasets. Thin driver over the bench
//! harness.
//!
//! ```sh
//! cargo run --release --example compressor_study
//! ```

fn main() -> zccl::Result<()> {
    let out = std::path::Path::new("results");
    for id in ["table1", "table3", "table4", "fig5", "fig7"] {
        zccl::coordinator::harness::run(id, out)?;
    }
    println!("full sweep: `zccl bench all`");
    Ok(())
}
